//! Semantic analysis + lowering: AST → slot-resolved [`ir::Unit`].
//!
//! Responsibilities: name resolution, type checking with IEC-style
//! implicit *widening* promotion only, constant folding (VAR CONSTANT +
//! array bounds), interface vtable construction, and enforcement of the
//! standard's restrictions (no recursion, no FB-in-FB fields, no scalar
//! VAR_IN_OUT, ADR only on statically allocated arrays).
//!
//! Slot discipline downstream passes rely on: POU locals (including
//! VAR_INPUT/VAR_IN_OUT and the implicit return slot 0) become
//! `Lv::Local` frame slots, while PROGRAM variables and FB
//! fields become `SelfField` instance accesses. The bytecode stage
//! maps slots 1:1 onto registers and allocates expression temporaries
//! with a per-statement watermark, so a statement's operand temps are
//! always consecutive and dead at the next statement — exactly the
//! shape `st::bytecode`'s superinstruction matchers pattern-match.
//! Changing how this module orders operand evaluation or assigns
//! slots silently de-fuses the hot kernels (the differential gate
//! stays correct either way; only the fused speedup disappears), and
//! the op mix is calibration-load-bearing (`tests/timing_calibration.rs`).

use std::collections::HashMap;
use std::sync::Arc;

use super::ast;
use super::ir::*;
use super::sema::SemaError;
use super::value::Init;

/// Lower a parsed file to an executable unit.
pub fn lower(file: &ast::File) -> Result<Unit, SemaError> {
    let mut lw = Lowerer::new(file);
    lw.collect_names()?;
    lw.lower_structs()?;
    lw.lower_ifaces()?;
    lw.collect_global_consts()?;
    lw.lower_globals()?;
    lw.lower_fb_shells()?;
    lw.lower_function_sigs()?;
    lw.lower_function_bodies()?;
    lw.lower_fb_methods()?;
    lw.lower_programs()?;
    lw.lower_configurations()?;
    lw.check_recursion()?;
    Ok(lw.unit)
}

fn err(line: u32, msg: impl Into<String>) -> SemaError {
    SemaError { line, message: msg.into() }
}

fn upper(s: &str) -> String {
    s.to_ascii_uppercase()
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Const {
    Int(i64),
    Real(f64),
    Bool(bool),
}

/// Call-graph node for the recursion ban.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Node {
    Func(usize),
    Method(usize, usize),
    FbBody(usize),
    Program(usize),
}

struct Lowerer<'a> {
    ast: &'a ast::File,
    unit: Unit,
    struct_ids: HashMap<String, usize>,
    iface_ids: HashMap<String, usize>,
    fb_ids: HashMap<String, usize>,
    func_ids: HashMap<String, usize>,
    global_consts: HashMap<String, Const>,
    edges: Vec<(Node, Node)>,
}

#[derive(Debug, Clone)]
enum Binding {
    Slot(u16, Ty),
    Konst(Const),
}

/// Per-body lowering context.
struct BodyCx {
    slots: Vec<VarDef>,
    names: HashMap<String, Binding>,
    /// FB/program fields when `self` is present.
    self_fields: Vec<VarDef>,
    n_inputs: usize,
    n_inouts: usize,
    loop_depth: usize,
    node: Node,
}

impl BodyCx {
    fn lookup(&self, name: &str) -> Option<Binding> {
        self.names.get(&upper(name)).cloned()
    }

    fn self_field_index(&self, name: &str) -> Option<(u16, &Ty)> {
        self.self_fields
            .iter()
            .position(|f| f.name.eq_ignore_ascii_case(name))
            .map(|i| (i as u16, &self.self_fields[i].ty))
    }
}

impl<'a> Lowerer<'a> {
    fn new(ast: &'a ast::File) -> Self {
        Lowerer {
            ast,
            unit: Unit::default(),
            struct_ids: HashMap::new(),
            iface_ids: HashMap::new(),
            fb_ids: HashMap::new(),
            func_ids: HashMap::new(),
            global_consts: HashMap::new(),
            edges: Vec::new(),
        }
    }

    // ------------------------------------------------------ collection
    fn collect_names(&mut self) -> Result<(), SemaError> {
        for (i, t) in self.ast.types.iter().enumerate() {
            if self.struct_ids.insert(upper(&t.name), i).is_some() {
                return Err(err(t.line, format!("duplicate type {}", t.name)));
            }
        }
        for (i, f) in self.ast.interfaces.iter().enumerate() {
            if self.iface_ids.insert(upper(&f.name), i).is_some() {
                return Err(err(f.line, format!("duplicate interface {}", f.name)));
            }
        }
        for (i, f) in self.ast.function_blocks.iter().enumerate() {
            if self.fb_ids.insert(upper(&f.name), i).is_some() {
                return Err(err(f.line, format!("duplicate FB {}", f.name)));
            }
        }
        for (i, f) in self.ast.functions.iter().enumerate() {
            if self.func_ids.insert(upper(&f.name), i).is_some() {
                return Err(err(f.line, format!("duplicate function {}", f.name)));
            }
        }
        Ok(())
    }

    // --------------------------------------------------- type resolve
    fn int_ty(name: &str) -> Option<IntTy> {
        Some(match name {
            "SINT" => IntTy::Sint,
            "USINT" => IntTy::Usint,
            "INT" => IntTy::Int,
            "UINT" => IntTy::Uint,
            "DINT" => IntTy::Dint,
            "UDINT" => IntTy::Udint,
            "LINT" => IntTy::Lint,
            "ULINT" => IntTy::Ulint,
            "BYTE" => IntTy::Byte,
            "WORD" => IntTy::Word,
            "DWORD" => IntTy::Dword,
            _ => return None,
        })
    }

    fn resolve_type(
        &self,
        tr: &ast::TypeRef,
        consts: &HashMap<String, Const>,
        line: u32,
    ) -> Result<Ty, SemaError> {
        match tr {
            ast::TypeRef::Named(n) => {
                let u = upper(n);
                if u == "BOOL" {
                    return Ok(Ty::Bool);
                }
                if u == "REAL" {
                    return Ok(Ty::Real);
                }
                if u == "LREAL" {
                    return Ok(Ty::LReal);
                }
                if let Some(it) = Self::int_ty(&u) {
                    return Ok(Ty::Int(it));
                }
                if let Some(&id) = self.struct_ids.get(&u) {
                    return Ok(Ty::Struct(id));
                }
                if let Some(&id) = self.iface_ids.get(&u) {
                    return Ok(Ty::Iface(id));
                }
                if let Some(&id) = self.fb_ids.get(&u) {
                    return Ok(Ty::Fb(id));
                }
                Err(err(line, format!("unknown type {n}")))
            }
            ast::TypeRef::StringTy => Ok(Ty::Str),
            ast::TypeRef::Pointer(elem) => {
                let e = self.resolve_type(elem, consts, line)?;
                match e {
                    Ty::Real | Ty::LReal | Ty::Int(_) => {
                        Ok(Ty::Ptr(Box::new(e)))
                    }
                    _ => Err(err(
                        line,
                        "POINTER TO is supported for numeric element types",
                    )),
                }
            }
            ast::TypeRef::Array(dims, elem) => {
                let e = self.resolve_type(elem, consts, line)?;
                match e {
                    Ty::Real | Ty::LReal | Ty::Int(_) | Ty::Bool
                    | Ty::Iface(_) => {}
                    _ => {
                        return Err(err(
                            line,
                            "ARRAY element must be numeric, BOOL, or an \
                             interface type",
                        ))
                    }
                }
                let mut bounds = Vec::new();
                for (lo, hi) in dims {
                    let lo = self.const_int(lo, consts, line)?;
                    let hi = self.const_int(hi, consts, line)?;
                    if hi < lo {
                        return Err(err(line, format!("bad array range {lo}..{hi}")));
                    }
                    bounds.push((lo, hi));
                }
                Ok(Ty::Arr(Box::new(e), Arc::new(bounds)))
            }
        }
    }

    // ------------------------------------------------------ const eval
    fn const_eval(
        &self,
        e: &ast::Expr,
        consts: &HashMap<String, Const>,
        line: u32,
    ) -> Result<Const, SemaError> {
        use ast::Expr as E;
        Ok(match e {
            E::IntLit(v) => Const::Int(*v),
            E::RealLit(v) => Const::Real(*v),
            E::BoolLit(b) => Const::Bool(*b),
            E::TypedLit(t, lit) => {
                if t == "REAL" || t == "LREAL" {
                    Const::Real(lit.parse().map_err(|_| {
                        err(line, format!("bad {t} literal {lit}"))
                    })?)
                } else {
                    Const::Int(lit.parse().map_err(|_| {
                        err(line, format!("bad {t} literal {lit}"))
                    })?)
                }
            }
            E::Name(n, l) => {
                let u = upper(n);
                consts
                    .get(&u)
                    .or_else(|| self.global_consts.get(&u))
                    .copied()
                    .ok_or_else(|| {
                        err(*l, format!("{n} is not a constant expression"))
                    })?
            }
            E::Unary(ast::UnOp::Neg, x, l) => {
                match self.const_eval(x, consts, *l)? {
                    Const::Int(v) => Const::Int(-v),
                    Const::Real(v) => Const::Real(-v),
                    Const::Bool(_) => {
                        return Err(err(*l, "cannot negate BOOL"))
                    }
                }
            }
            E::Unary(ast::UnOp::Not, x, l) => {
                match self.const_eval(x, consts, *l)? {
                    Const::Bool(b) => Const::Bool(!b),
                    _ => return Err(err(*l, "NOT needs BOOL")),
                }
            }
            E::Binary(op, a, b, l) => {
                let a = self.const_eval(a, consts, *l)?;
                let b = self.const_eval(b, consts, *l)?;
                const_bin(*op, a, b, *l)?
            }
            other => {
                return Err(err(
                    other.line().max(line),
                    "unsupported constant expression",
                ))
            }
        })
    }

    fn const_int(
        &self,
        e: &ast::Expr,
        consts: &HashMap<String, Const>,
        line: u32,
    ) -> Result<i64, SemaError> {
        match self.const_eval(e, consts, line)? {
            Const::Int(v) => Ok(v),
            _ => Err(err(line, "expected an integer constant")),
        }
    }

    // --------------------------------------------------------- structs
    fn lower_structs(&mut self) -> Result<(), SemaError> {
        // Two passes so structs can nest (no cycles allowed).
        for t in &self.ast.types {
            self.unit.structs.push(StructDef { name: t.name.clone(), fields: vec![] });
        }
        let empty = HashMap::new();
        for (i, t) in self.ast.types.iter().enumerate() {
            let mut fields = Vec::new();
            for f in &t.fields {
                let ty = self.resolve_type(&f.ty, &empty, f.line)?;
                if let Ty::Struct(sid) = ty {
                    if sid == i {
                        return Err(err(f.line, "recursive struct"));
                    }
                }
                if matches!(ty, Ty::Fb(_)) {
                    return Err(err(f.line, "FB instance fields in structs are not supported"));
                }
                let init = self.init_value(&ty, f.init.as_ref(), &empty, f.line)?;
                fields.push(VarDef { name: f.name.clone(), ty, init });
            }
            self.unit.structs[i].fields = fields;
        }
        Ok(())
    }

    fn lower_ifaces(&mut self) -> Result<(), SemaError> {
        for f in &self.ast.interfaces {
            self.unit.ifaces.push(IfaceDef {
                name: f.name.clone(),
                methods: f.methods.iter().map(|m| upper(&m.name)).collect(),
            });
        }
        Ok(())
    }

    fn collect_global_consts(&mut self) -> Result<(), SemaError> {
        for blk in &self.ast.globals {
            if !blk.constant {
                continue;
            }
            for d in &blk.decls {
                let init = d.init.as_ref().ok_or_else(|| {
                    err(d.line, format!("constant {} needs an initializer", d.name))
                })?;
                let e = match init {
                    ast::Initializer::Expr(e) => e,
                    _ => return Err(err(d.line, "constant must be scalar")),
                };
                let c = self.const_eval(e, &HashMap::new(), d.line)?;
                self.global_consts.insert(upper(&d.name), c);
            }
        }
        Ok(())
    }

    fn lower_globals(&mut self) -> Result<(), SemaError> {
        let empty = HashMap::new();
        for blk in &self.ast.globals {
            if blk.constant {
                continue;
            }
            for d in &blk.decls {
                let ty = self.resolve_type(&d.ty, &self.global_consts.clone(), d.line)?;
                let init =
                    self.init_value(&ty, d.init.as_ref(), &empty, d.line)?;
                self.unit.globals.push(VarDef { name: d.name.clone(), ty, init });
            }
        }
        Ok(())
    }

    /// Build the initial-value [`Init`] template for a declaration.
    fn init_value(
        &self,
        ty: &Ty,
        init: Option<&ast::Initializer>,
        consts: &HashMap<String, Const>,
        line: u32,
    ) -> Result<Init, SemaError> {
        match init {
            None => Ok(self.zero_value(ty)),
            Some(ast::Initializer::Expr(e)) => {
                let c = self.const_eval(e, consts, line)?;
                match (ty, c) {
                    (Ty::Bool, Const::Bool(b)) => Ok(Init::Bool(b)),
                    (Ty::Int(it), Const::Int(v)) => Ok(Init::Int(it.wrap(v))),
                    (Ty::Real, Const::Int(v)) => Ok(Init::Real(v as f32)),
                    (Ty::Real, Const::Real(v)) => Ok(Init::Real(v as f32)),
                    (Ty::LReal, Const::Int(v)) => Ok(Init::LReal(v as f64)),
                    (Ty::LReal, Const::Real(v)) => Ok(Init::LReal(v)),
                    _ => Err(err(line, "initializer type mismatch")),
                }
            }
            Some(ast::Initializer::Array(items)) => {
                let (elem, len) = match ty {
                    Ty::Arr(e, _) => (e.as_ref(), ty.arr_len().unwrap()),
                    _ => return Err(err(line, "array initializer on non-array")),
                };
                let mut vals: Vec<Const> = Vec::new();
                for (rep, e) in items {
                    let v = self.const_eval(e, consts, line)?;
                    let n = match rep {
                        Some(r) => self.const_int(r, consts, line)? as usize,
                        None => 1,
                    };
                    for _ in 0..n {
                        vals.push(v);
                    }
                }
                if vals.len() > len {
                    return Err(err(line, "too many array initializer elements"));
                }
                while vals.len() < len {
                    vals.push(Const::Int(0));
                }
                match elem {
                    Ty::Real => Ok(Init::ArrF32(
                        vals.iter().map(|c| const_f64(*c) as f32).collect(),
                    )),
                    Ty::LReal => Ok(Init::ArrF64(
                        vals.iter().map(|c| const_f64(*c)).collect(),
                    )),
                    Ty::Int(_) | Ty::Bool => Ok(Init::ArrInt(
                        vals.iter().map(|c| const_i64(*c)).collect(),
                    )),
                    _ => Err(err(line, "array initializer element type")),
                }
            }
            Some(ast::Initializer::Struct(fields)) => {
                let sid = match ty {
                    Ty::Struct(id) => *id,
                    _ => return Err(err(line, "struct initializer on non-struct")),
                };
                let def = self.unit.structs[sid].clone();
                let mut vals: Vec<Init> =
                    def.fields.iter().map(|f| f.init.clone()).collect();
                for (name, e) in fields {
                    let idx = def
                        .fields
                        .iter()
                        .position(|f| f.name.eq_ignore_ascii_case(name))
                        .ok_or_else(|| {
                            err(line, format!("no struct field {name}"))
                        })?;
                    vals[idx] = self.init_value(
                        &def.fields[idx].ty,
                        Some(&ast::Initializer::Expr(e.clone())),
                        consts,
                        line,
                    )?;
                }
                Ok(Init::Struct(vals))
            }
        }
    }

    fn zero_value(&self, ty: &Ty) -> Init {
        match ty {
            Ty::Bool => Init::Bool(false),
            Ty::Int(_) => Init::Int(0),
            Ty::Real => Init::Real(0.0),
            Ty::LReal => Init::LReal(0.0),
            Ty::Str => Init::Str(Arc::from("")),
            Ty::Arr(elem, _) => {
                let len = ty.arr_len().unwrap();
                match elem.as_ref() {
                    Ty::Real => Init::ArrF32(vec![0.0; len]),
                    Ty::LReal => Init::ArrF64(vec![0.0; len]),
                    Ty::Int(_) | Ty::Bool => Init::ArrInt(vec![0; len]),
                    Ty::Iface(_) => Init::ArrRef(vec![Init::Null; len]),
                    _ => unreachable!("checked in resolve_type"),
                }
            }
            Ty::Struct(id) => Init::Struct(
                self.unit.structs[*id]
                    .fields
                    .iter()
                    .map(|f| f.init.clone())
                    .collect(),
            ),
            Ty::Fb(_) | Ty::Iface(_) | Ty::Ptr(_) => Init::Null,
        }
    }

    // ------------------------------------------------------- FB shells
    /// First pass over FBs: fields + vtable skeletons (bodies later, so
    /// methods can call other FBs' methods and functions).
    fn lower_fb_shells(&mut self) -> Result<(), SemaError> {
        for fb in &self.ast.function_blocks {
            let mut fields = Vec::new();
            let mut input_fields = Vec::new();
            let mut output_fields = Vec::new();
            let mut consts = HashMap::new();
            for blk in &fb.blocks {
                for d in &blk.decls {
                    if blk.constant {
                        let e = match d.init.as_ref() {
                            Some(ast::Initializer::Expr(e)) => e,
                            _ => return Err(err(d.line, "bad constant")),
                        };
                        let c = self.const_eval(e, &consts, d.line)?;
                        consts.insert(upper(&d.name), c);
                        continue;
                    }
                    let ty = self.resolve_type(&d.ty, &consts, d.line)?;
                    if matches!(ty, Ty::Fb(_)) {
                        return Err(err(
                            d.line,
                            "FB instance fields inside FBs are not supported \
                             (flatten the composition)",
                        ));
                    }
                    let init = self.init_value(&ty, d.init.as_ref(), &consts, d.line)?;
                    let idx = fields.len() as u16;
                    match blk.kind {
                        ast::VarKind::Input => input_fields.push(idx),
                        ast::VarKind::Output => output_fields.push(idx),
                        ast::VarKind::InOut => {
                            return Err(err(d.line, "VAR_IN_OUT FB fields unsupported"))
                        }
                        _ => {}
                    }
                    fields.push(VarDef { name: d.name.clone(), ty, init });
                }
            }
            let n_ifaces = self.unit.ifaces.len();
            self.unit.fbs.push(FbDef {
                name: fb.name.clone(),
                fields,
                methods: Vec::new(),
                body: None,
                input_fields,
                output_fields,
                vtables: vec![None; n_ifaces],
            });
        }
        Ok(())
    }

    fn lower_function_sigs(&mut self) -> Result<(), SemaError> {
        // Full signatures (slot layouts) before any body is lowered, so
        // calls between POUs type-check regardless of declaration order.
        for (i, f) in self.ast.functions.iter().enumerate() {
            let cx = self.body_cx(f, None, &[], Node::Func(i))?;
            self.unit.funcs.push(FuncDef {
                name: f.name.clone(),
                slots: cx.slots,
                has_ret: f.ret.is_some(),
                n_inputs: cx.n_inputs,
                n_inouts: cx.n_inouts,
                body: Vec::new(),
            });
        }
        // Same for FB method signatures (+ vtables, which only need
        // names + signatures).
        for (fb_i, fb) in self.ast.function_blocks.iter().enumerate() {
            let fields = self.unit.fbs[fb_i].fields.clone();
            let mut methods = Vec::new();
            for (m_i, m) in fb.methods.iter().enumerate() {
                let cx =
                    self.body_cx(m, Some(fb_i), &fields, Node::Method(fb_i, m_i))?;
                methods.push(FuncDef {
                    name: m.name.clone(),
                    slots: cx.slots,
                    has_ret: m.ret.is_some(),
                    n_inputs: cx.n_inputs,
                    n_inouts: cx.n_inouts,
                    body: Vec::new(),
                });
            }
            self.unit.fbs[fb_i].methods = methods;
            for iname in &fb.implements {
                let iid = *self.iface_ids.get(&upper(iname)).ok_or_else(|| {
                    err(fb.line, format!("unknown interface {iname}"))
                })?;
                let idef = self.unit.ifaces[iid].clone();
                let mut table = Vec::new();
                for mname in &idef.methods {
                    let midx = self.unit.fbs[fb_i]
                        .methods
                        .iter()
                        .position(|m| upper(&m.name) == *mname)
                        .ok_or_else(|| {
                            err(
                                fb.line,
                                format!(
                                    "{} does not implement method {} of {}",
                                    fb.name, mname, idef.name
                                ),
                            )
                        })?;
                    table.push(midx);
                }
                self.unit.fbs[fb_i].vtables[iid] = Some(table);
            }
        }
        Ok(())
    }

    // ----------------------------------------------------- body common
    /// Build a BodyCx for a POU. `self_fields`: FB/program fields.
    fn body_cx(
        &self,
        pou: &ast::PouDecl,
        self_fb: Option<usize>,
        self_fields: &[VarDef],
        node: Node,
    ) -> Result<BodyCx, SemaError> {
        let _ = self_fb;
        let mut cx = BodyCx {
            slots: Vec::new(),
            names: HashMap::new(),
            self_fields: self_fields.to_vec(),
            n_inputs: 0,
            n_inouts: 0,
            loop_depth: 0,
            node,
        };
        let mut consts: HashMap<String, Const> = HashMap::new();

        // Slot 0: return value.
        if let Some(ret) = &pou.ret {
            let ty = self.resolve_type(ret, &consts, pou.line)?;
            cx.names
                .insert(upper(&pou.name), Binding::Slot(0, ty.clone()));
            cx.slots.push(VarDef {
                name: pou.name.clone(),
                init: self.zero_value(&ty),
                ty,
            });
        } else {
            // keep slot 0 reserved for uniformity
            cx.slots.push(VarDef {
                name: "__ret".into(),
                ty: Ty::Bool,
                init: Init::Bool(false),
            });
        }

        // Inputs, then in-outs, then locals.
        for pass in 0..3 {
            for blk in &pou.blocks {
                let want = match pass {
                    0 => blk.kind == ast::VarKind::Input,
                    1 => blk.kind == ast::VarKind::InOut,
                    _ => matches!(blk.kind, ast::VarKind::Local),
                };
                if !want {
                    continue;
                }
                if blk.kind == ast::VarKind::Output {
                    return Err(err(pou.line, "VAR_OUTPUT on POUs unsupported; use the return value"));
                }
                for d in &blk.decls {
                    if blk.constant {
                        let e = match d.init.as_ref() {
                            Some(ast::Initializer::Expr(e)) => e,
                            _ => return Err(err(d.line, "bad constant")),
                        };
                        let c = self.const_eval(e, &consts, d.line)?;
                        consts.insert(upper(&d.name), c);
                        cx.names.insert(upper(&d.name), Binding::Konst(c));
                        continue;
                    }
                    let ty = self.resolve_type(&d.ty, &consts, d.line)?;
                    if blk.kind == ast::VarKind::InOut
                        && !matches!(ty, Ty::Arr(..) | Ty::Struct(_))
                    {
                        return Err(err(
                            d.line,
                            "VAR_IN_OUT supports ARRAY/STRUCT only",
                        ));
                    }
                    let init = self.init_value(&ty, d.init.as_ref(), &consts, d.line)?;
                    let slot = cx.slots.len() as u16;
                    cx.names
                        .insert(upper(&d.name), Binding::Slot(slot, ty.clone()));
                    cx.slots.push(VarDef { name: d.name.clone(), ty, init });
                    match pass {
                        0 => cx.n_inputs += 1,
                        1 => cx.n_inouts += 1,
                        _ => {}
                    }
                }
            }
        }
        Ok(cx)
    }

    fn lower_function_bodies(&mut self) -> Result<(), SemaError> {
        for (i, f) in self.ast.functions.iter().enumerate() {
            let mut cx = self.body_cx(f, None, &[], Node::Func(i))?;
            let body = self.lower_block(&f.body, &mut cx)?;
            let fd = &mut self.unit.funcs[i];
            fd.slots = cx.slots;
            fd.n_inputs = cx.n_inputs;
            fd.n_inouts = cx.n_inouts;
            fd.body = body;
        }
        Ok(())
    }

    fn lower_fb_methods(&mut self) -> Result<(), SemaError> {
        for (fb_i, fb) in self.ast.function_blocks.iter().enumerate() {
            let fields = self.unit.fbs[fb_i].fields.clone();
            for (m_i, m) in fb.methods.iter().enumerate() {
                let mut cx =
                    self.body_cx(m, Some(fb_i), &fields, Node::Method(fb_i, m_i))?;
                let body = self.lower_block(&m.body, &mut cx)?;
                self.unit.fbs[fb_i].methods[m_i].body = body;
            }
            // FB body (optional).
            let fb_body = if fb.body.is_empty() {
                None
            } else {
                let pou = ast::PouDecl {
                    name: format!("{}__body", fb.name),
                    ret: None,
                    blocks: vec![],
                    body: fb.body.clone(),
                    line: fb.line,
                };
                let mut cx =
                    self.body_cx(&pou, Some(fb_i), &fields, Node::FbBody(fb_i))?;
                let body = self.lower_block(&fb.body, &mut cx)?;
                Some(FuncDef {
                    name: pou.name,
                    slots: cx.slots,
                    has_ret: false,
                    n_inputs: 0,
                    n_inouts: 0,
                    body,
                })
            };
            self.unit.fbs[fb_i].body = fb_body;
        }
        Ok(())
    }

    fn lower_programs(&mut self) -> Result<(), SemaError> {
        for (p_i, p) in self.ast.programs.iter().enumerate() {
            // Program VARs are persistent fields (retained across scans).
            let mut fields = Vec::new();
            let mut consts = HashMap::new();
            for blk in &p.blocks {
                for d in &blk.decls {
                    if blk.constant {
                        let e = match d.init.as_ref() {
                            Some(ast::Initializer::Expr(e)) => e,
                            _ => return Err(err(d.line, "bad constant")),
                        };
                        let c = self.const_eval(e, &consts, d.line)?;
                        consts.insert(upper(&d.name), c);
                        continue;
                    }
                    let ty = self.resolve_type(&d.ty, &consts, d.line)?;
                    let init = self.init_value(&ty, d.init.as_ref(), &consts, d.line)?;
                    fields.push(VarDef { name: d.name.clone(), ty, init });
                }
            }
            let pou = ast::PouDecl {
                name: p.name.clone(),
                ret: None,
                blocks: vec![],
                body: p.body.clone(),
                line: p.line,
            };
            let mut cx =
                self.body_cx(&pou, Some(usize::MAX), &fields, Node::Program(p_i))?;
            // re-expose program constants
            for (k, v) in &consts {
                cx.names.insert(k.clone(), Binding::Konst(*v));
            }
            let body = self.lower_block(&p.body, &mut cx)?;
            self.unit.programs.push(ProgramDef {
                name: p.name.clone(),
                fields,
                body: FuncDef {
                    name: p.name.clone(),
                    slots: cx.slots,
                    has_ret: false,
                    n_inputs: 0,
                    n_inouts: 0,
                    body,
                },
            });
        }
        Ok(())
    }

    // =================================================== statements
    fn lower_block(
        &mut self,
        stmts: &[ast::Stmt],
        cx: &mut BodyCx,
    ) -> Result<Vec<St>, SemaError> {
        let mut out = Vec::new();
        for s in stmts {
            if let Some(st) = self.lower_stmt(s, cx)? {
                out.push(st);
            }
        }
        Ok(out)
    }

    fn lower_stmt(
        &mut self,
        s: &ast::Stmt,
        cx: &mut BodyCx,
    ) -> Result<Option<St>, SemaError> {
        Ok(Some(match s {
            ast::Stmt::Empty => return Ok(None),
            ast::Stmt::Assign { target, value, line } => {
                let (lv, lty) = self.lower_lv(target, cx)?;
                // Struct literals are typed by the assignment target.
                if let ast::Expr::StructLit(fields, sl_line) = value {
                    let sid = match lty {
                        Ty::Struct(id) => id,
                        other => {
                            return Err(err(
                                *sl_line,
                                format!("struct literal assigned to {other:?}"),
                            ))
                        }
                    };
                    let ex = self.lower_struct_lit(sid, fields, cx, *sl_line)?;
                    return Ok(Some(St::Assign(lv, ex, true)));
                }
                let (ex, ety) = self.lower_expr(value, cx)?;
                let ex = coerce(ex, &ety, &lty, *line)?;
                let copy = matches!(lty, Ty::Arr(..) | Ty::Struct(_));
                St::Assign(lv, ex, copy)
            }
            ast::Stmt::If { arms, else_body, line } => {
                let mut iarms = Vec::new();
                for (c, b) in arms {
                    let (ce, cty) = self.lower_expr(c, cx)?;
                    expect_bool(&cty, *line)?;
                    iarms.push((ce, self.lower_block(b, cx)?));
                }
                St::If(iarms, self.lower_block(else_body, cx)?)
            }
            ast::Stmt::Case { scrutinee, arms, else_body, line } => {
                let (se, sty) = self.lower_expr(scrutinee, cx)?;
                if !matches!(sty, Ty::Int(_)) {
                    return Err(err(*line, "CASE needs an integer selector"));
                }
                let mut iarms = Vec::new();
                for (labels, body) in arms {
                    let mut ranges = Vec::new();
                    for l in labels {
                        match l {
                            ast::CaseLabel::Single(e) => {
                                let v = self.const_int_in_cx(e, cx, *line)?;
                                ranges.push((v, v));
                            }
                            ast::CaseLabel::Range(a, b) => {
                                let a = self.const_int_in_cx(a, cx, *line)?;
                                let b = self.const_int_in_cx(b, cx, *line)?;
                                ranges.push((a, b));
                            }
                        }
                    }
                    iarms.push((Arc::new(ranges), self.lower_block(body, cx)?));
                }
                St::Case(se, iarms, self.lower_block(else_body, cx)?)
            }
            ast::Stmt::For { var, from, to, by, body, line } => {
                let (var_lv, var_ty) =
                    self.lower_lv(&ast::Expr::Name(var.clone(), *line), cx)?;
                if !matches!(var_ty, Ty::Int(_)) {
                    return Err(err(
                        *line,
                        format!("FOR variable {var} must be an integer"),
                    ));
                }
                let (fe, fty) = self.lower_expr(from, cx)?;
                let (te, tty) = self.lower_expr(to, cx)?;
                expect_int(&fty, *line)?;
                expect_int(&tty, *line)?;
                let by = match by {
                    Some(b) => {
                        let (be, bty) = self.lower_expr(b, cx)?;
                        expect_int(&bty, *line)?;
                        Some(be)
                    }
                    None => None,
                };
                cx.loop_depth += 1;
                let body = self.lower_block(body, cx)?;
                cx.loop_depth -= 1;
                St::For { var: var_lv, from: fe, to: te, by, body }
            }
            ast::Stmt::While { cond, body, line } => {
                let (ce, cty) = self.lower_expr(cond, cx)?;
                expect_bool(&cty, *line)?;
                cx.loop_depth += 1;
                let body = self.lower_block(body, cx)?;
                cx.loop_depth -= 1;
                St::While(ce, body)
            }
            ast::Stmt::Repeat { body, until, line } => {
                cx.loop_depth += 1;
                let body = self.lower_block(body, cx)?;
                cx.loop_depth -= 1;
                let (ue, uty) = self.lower_expr(until, cx)?;
                expect_bool(&uty, *line)?;
                St::Repeat(body, ue)
            }
            ast::Stmt::Exit { line } => {
                if cx.loop_depth == 0 {
                    return Err(err(*line, "EXIT outside a loop"));
                }
                St::Exit
            }
            ast::Stmt::Continue { line } => {
                if cx.loop_depth == 0 {
                    return Err(err(*line, "CONTINUE outside a loop"));
                }
                St::Continue
            }
            ast::Stmt::Return { .. } => St::Return,
            ast::Stmt::Call { expr, line } => {
                // FB invocation `inst(...)` or plain call.
                if let ast::Expr::Call { callee, args, .. } = expr {
                    if let Some(st) =
                        self.try_fb_invoke(callee, args, cx, *line)?
                    {
                        return Ok(Some(st));
                    }
                }
                let (ex, _) = self.lower_expr(expr, cx)?;
                St::Expr(ex)
            }
        }))
    }

    // -------------------------------------------- §2.7 configurations
    /// Lower `CONFIGURATION` blocks to the unit's [`TaskModel`]
    /// (`super::tasks`). Programs must already be lowered: bindings
    /// resolve to program-definition indices, and `SINGLE` triggers to
    /// global slots.
    fn lower_configurations(&mut self) -> Result<(), SemaError> {
        use super::tasks::{
            parse_duration_us, ProgramBinding, TaskDef, TaskModel, Trigger,
        };
        let cfg = match self.ast.configurations.as_slice() {
            [] => return Ok(()),
            [one] => one,
            [_, second, ..] => {
                return Err(err(
                    second.line,
                    "multiple CONFIGURATION blocks are not supported",
                ))
            }
        };
        let res = match cfg.resources.as_slice() {
            [one] => one,
            [] => {
                return Err(err(
                    cfg.line,
                    format!(
                        "CONFIGURATION {} declares no RESOURCE",
                        cfg.name
                    ),
                ))
            }
            [_, second, ..] => {
                return Err(err(
                    second.line,
                    "multiple RESOURCE blocks are not supported",
                ))
            }
        };

        let consts = HashMap::new();
        let mut tasks: Vec<TaskDef> = Vec::new();
        for t in &res.tasks {
            if tasks.iter().any(|d| d.name.eq_ignore_ascii_case(&t.name)) {
                return Err(err(
                    t.line,
                    format!("duplicate TASK {}", t.name),
                ));
            }
            let priority = match &t.priority {
                Some(e) => {
                    let p = self.const_int(e, &consts, t.line)?;
                    if !(0..=u32::MAX as i64).contains(&p) {
                        return Err(err(
                            t.line,
                            format!(
                                "TASK {}: PRIORITY must be non-negative, \
                                 got {p}",
                                t.name
                            ),
                        ));
                    }
                    p as u32
                }
                None => 0,
            };
            let trigger = match (&t.interval, &t.single) {
                (Some(_), Some(_)) => {
                    return Err(err(
                        t.line,
                        format!(
                            "TASK {}: INTERVAL and SINGLE are mutually \
                             exclusive",
                            t.name
                        ),
                    ))
                }
                (None, None) => {
                    return Err(err(
                        t.line,
                        format!(
                            "TASK {} needs an INTERVAL or SINGLE trigger",
                            t.name
                        ),
                    ))
                }
                (Some(lit), None) => {
                    let us =
                        parse_duration_us(lit).ok_or_else(|| {
                            err(
                                t.line,
                                format!(
                                    "TASK {}: bad INTERVAL duration \
                                     T#{lit}",
                                    t.name
                                ),
                            )
                        })?;
                    if us <= 0 {
                        return Err(err(
                            t.line,
                            format!(
                                "TASK {}: INTERVAL must be positive, \
                                 got T#{lit}",
                                t.name
                            ),
                        ));
                    }
                    Trigger::Cyclic { interval_us: us as u64 }
                }
                (None, Some(g)) => {
                    let gid =
                        self.unit.find_global(g).ok_or_else(|| {
                            err(
                                t.line,
                                format!(
                                    "TASK {}: SINGLE trigger {g} is not \
                                     a global variable",
                                    t.name
                                ),
                            )
                        })?;
                    if self.unit.globals[gid].ty != Ty::Bool {
                        return Err(err(
                            t.line,
                            format!(
                                "TASK {}: SINGLE trigger {g} must be a \
                                 global BOOL",
                                t.name
                            ),
                        ));
                    }
                    Trigger::Single { global: gid }
                }
            };
            tasks.push(TaskDef {
                name: t.name.clone(),
                trigger,
                priority,
                programs: Vec::new(),
            });
        }

        // Program-instance bindings; unbound instances freewheel at
        // the lowest priority (IEC default), each as its own synthetic
        // task so the scheduler accounts them separately.
        let mut seen_inst: Vec<String> = Vec::new();
        let mut bound_types: Vec<usize> = Vec::new();
        let mut free: Vec<TaskDef> = Vec::new();
        for b in &res.programs {
            if seen_inst.iter().any(|n| n.eq_ignore_ascii_case(&b.name)) {
                return Err(err(
                    b.line,
                    format!("duplicate program instance {}", b.name),
                ));
            }
            seen_inst.push(b.name.clone());
            let pid = self
                .unit
                .find_program(&b.program_type)
                .ok_or_else(|| {
                    err(
                        b.line,
                        format!(
                            "program instance {} has unknown PROGRAM \
                             type {}",
                            b.name, b.program_type
                        ),
                    )
                })?;
            // The host allocates exactly one instance per PROGRAM
            // definition; two bindings of one type would alias state.
            if bound_types.contains(&pid) {
                return Err(err(
                    b.line,
                    format!(
                        "PROGRAM type {} is bound more than once (one \
                         instance per PROGRAM definition)",
                        b.program_type
                    ),
                ));
            }
            bound_types.push(pid);
            let binding = ProgramBinding {
                instance: b.name.clone(),
                program: pid,
            };
            match &b.task {
                Some(tname) => {
                    let ti = tasks
                        .iter()
                        .position(|d| d.name.eq_ignore_ascii_case(tname))
                        .ok_or_else(|| {
                            err(
                                b.line,
                                format!(
                                    "program instance {} bound to \
                                     undeclared TASK {tname}",
                                    b.name
                                ),
                            )
                        })?;
                    tasks[ti].programs.push(binding);
                }
                None => free.push(TaskDef {
                    name: format!("__free_{}", b.name),
                    trigger: Trigger::Freewheeling,
                    priority: u32::MAX,
                    programs: vec![binding],
                }),
            }
        }
        tasks.extend(free);

        self.unit.tasks = Some(TaskModel {
            config_name: cfg.name.clone(),
            resource_name: res.name.clone(),
            processor: res.on.clone(),
            tasks,
        });
        Ok(())
    }

    fn const_int_in_cx(
        &self,
        e: &ast::Expr,
        cx: &BodyCx,
        line: u32,
    ) -> Result<i64, SemaError> {
        // Allow local constant names in CASE labels.
        let mut consts = HashMap::new();
        for (k, v) in &cx.names {
            if let Binding::Konst(c) = v {
                consts.insert(k.clone(), *c);
            }
        }
        self.const_int(e, &consts, line)
    }

    /// `inst(a := x, out => y);` — FB invocation statement.
    fn try_fb_invoke(
        &mut self,
        callee: &ast::Expr,
        args: &[ast::Arg],
        cx: &mut BodyCx,
        line: u32,
    ) -> Result<Option<St>, SemaError> {
        // Callee must be a plain lvalue of FB type (not a method call).
        let (fb_ex, fb_ty) = match self.try_lower_expr(callee, cx) {
            Ok(x) => x,
            Err(_) => return Ok(None),
        };
        let fb_id = match fb_ty {
            Ty::Fb(id) => id,
            _ => return Ok(None),
        };
        if self.unit.fbs[fb_id].body.is_none() {
            return Err(err(
                line,
                format!("FB {} has no body to invoke", self.unit.fbs[fb_id].name),
            ));
        }
        self.edges.push((cx.node, Node::FbBody(fb_id)));
        let fbdef = self.unit.fbs[fb_id].clone();
        let mut inputs = Vec::new();
        let mut outputs = Vec::new();
        for a in args {
            let name = a.name.as_ref().ok_or_else(|| {
                err(line, "FB invocation arguments must be named")
            })?;
            let fidx = fbdef
                .fields
                .iter()
                .position(|f| f.name.eq_ignore_ascii_case(name))
                .ok_or_else(|| {
                    err(line, format!("FB {} has no field {name}", fbdef.name))
                })? as u16;
            let fty = &fbdef.fields[fidx as usize].ty;
            if a.is_output {
                let (lv, lty) = self.lower_lv(&a.value, cx)?;
                if lty != *fty {
                    return Err(err(line, format!("output {name} type mismatch")));
                }
                outputs.push((fidx, lv));
            } else {
                if !fbdef.input_fields.contains(&fidx) {
                    return Err(err(
                        line,
                        format!("{name} is not a VAR_INPUT of {}", fbdef.name),
                    ));
                }
                let (ex, ety) = self.lower_expr(&a.value, cx)?;
                let ex = coerce(ex, &ety, fty, line)?;
                let copy = matches!(fty, Ty::Arr(..) | Ty::Struct(_));
                inputs.push((fidx, ex, copy));
            }
        }
        Ok(Some(St::FbInvoke { fb: fb_ex, fb_id, inputs, outputs, line }))
    }

    // =================================================== expressions
    fn try_lower_expr(
        &mut self,
        e: &ast::Expr,
        cx: &mut BodyCx,
    ) -> Result<(Ex, Ty), SemaError> {
        self.lower_expr(e, cx)
    }

    fn lower_expr(
        &mut self,
        e: &ast::Expr,
        cx: &mut BodyCx,
    ) -> Result<(Ex, Ty), SemaError> {
        use ast::Expr as E;
        match e {
            E::IntLit(v) => Ok((Ex::KInt(*v), Ty::Int(IntTy::Dint))),
            E::RealLit(v) => Ok((Ex::KReal(*v as f32), Ty::Real)),
            E::BoolLit(b) => Ok((Ex::KBool(*b), Ty::Bool)),
            E::StrLit(s) => Ok((Ex::KStr(Arc::from(s.as_str())), Ty::Str)),
            E::NullLit => Ok((Ex::KNull, Ty::Ptr(Box::new(Ty::Real)))),
            E::TypedLit(tname, lit) => {
                if tname == "REAL" {
                    let v: f64 = lit.parse().map_err(|_| err(0, "bad REAL#"))?;
                    Ok((Ex::KReal(v as f32), Ty::Real))
                } else if tname == "LREAL" {
                    let v: f64 = lit.parse().map_err(|_| err(0, "bad LREAL#"))?;
                    Ok((Ex::KLReal(v), Ty::LReal))
                } else if let Some(it) = Self::int_ty(tname) {
                    let v: i64 = lit.parse().map_err(|_| err(0, "bad int literal"))?;
                    Ok((Ex::KInt(it.wrap(v)), Ty::Int(it)))
                } else if tname == "BOOL" {
                    Ok((Ex::KBool(lit == "1" || upper(lit) == "TRUE"), Ty::Bool))
                } else {
                    Err(err(0, format!("unsupported typed literal {tname}#")))
                }
            }
            E::Name(n, line) => {
                match cx.lookup(n) {
                    Some(Binding::Slot(s, ty)) => Ok((Ex::Local(s), ty)),
                    Some(Binding::Konst(c)) => Ok(const_to_ex(c)),
                    None => {
                        if let Some((i, ty)) = cx.self_field_index(n) {
                            let ty = ty.clone();
                            return Ok((Ex::SelfField(i), ty));
                        }
                        if let Some(c) = self.global_consts.get(&upper(n)) {
                            return Ok(const_to_ex(*c));
                        }
                        if let Some(g) =
                            self.unit.globals.iter().position(|gv| {
                                gv.name.eq_ignore_ascii_case(n)
                            })
                        {
                            let ty = self.unit.globals[g].ty.clone();
                            return Ok((Ex::Global(g as u16), ty));
                        }
                        Err(err(*line, format!("unknown name {n}")))
                    }
                }
            }
            E::Member(base, field, line) => {
                let (be, bty) = self.lower_expr(base, cx)?;
                match bty {
                    Ty::Struct(sid) => {
                        let sd = &self.unit.structs[sid];
                        let idx = sd
                            .fields
                            .iter()
                            .position(|f| f.name.eq_ignore_ascii_case(field))
                            .ok_or_else(|| {
                                err(*line, format!("{} has no field {field}", sd.name))
                            })?;
                        let fty = sd.fields[idx].ty.clone();
                        Ok((Ex::Field(Box::new(be), idx as u16), fty))
                    }
                    Ty::Fb(fbid) => {
                        let fd = &self.unit.fbs[fbid];
                        let idx = fd
                            .fields
                            .iter()
                            .position(|f| f.name.eq_ignore_ascii_case(field))
                            .ok_or_else(|| {
                                err(*line, format!("{} has no field {field}", fd.name))
                            })?;
                        let fty = fd.fields[idx].ty.clone();
                        Ok((Ex::FbField(Box::new(be), idx as u16), fty))
                    }
                    other => Err(err(
                        *line,
                        format!("member access on non-struct/FB type {other:?}"),
                    )),
                }
            }
            E::Index(base, idxs, line) => {
                let (be, bty) = self.lower_expr(base, cx)?;
                match &bty {
                    Ty::Arr(elem, dims) => {
                        let (flat, len) =
                            self.flat_index(idxs, dims, cx, *line)?;
                        let kind = elem_kind(elem, *line)?;
                        Ok((
                            Ex::Idx(Box::new(be), Box::new(flat), len, kind, *line),
                            (**elem).clone(),
                        ))
                    }
                    Ty::Ptr(elem) => {
                        // pointer indexing p[i]
                        if idxs.len() != 1 {
                            return Err(err(*line, "pointer index takes one subscript"));
                        }
                        let (ie, ity) = self.lower_expr(&idxs[0], cx)?;
                        expect_int(&ity, *line)?;
                        let pk = ptr_kind(elem, *line)?;
                        Ok((
                            Ex::PtrLoad(Box::new(be), Some(Box::new(ie)), pk, *line),
                            (**elem).clone(),
                        ))
                    }
                    other => {
                        Err(err(*line, format!("indexing non-array {other:?}")))
                    }
                }
            }
            E::Deref(base, line) => {
                let (be, bty) = self.lower_expr(base, cx)?;
                match bty {
                    Ty::Ptr(elem) => {
                        let pk = ptr_kind(&elem, *line)?;
                        Ok((Ex::PtrLoad(Box::new(be), None, pk, *line), *elem))
                    }
                    other => {
                        Err(err(*line, format!("deref of non-pointer {other:?}")))
                    }
                }
            }
            E::Unary(op, x, line) => {
                let (xe, xty) = self.lower_expr(x, cx)?;
                match op {
                    ast::UnOp::Neg => match xty {
                        Ty::Real => Ok((Ex::NegF32(Box::new(xe)), Ty::Real)),
                        Ty::LReal => Ok((Ex::NegF64(Box::new(xe)), Ty::LReal)),
                        Ty::Int(it) => Ok((Ex::NegInt(Box::new(xe)), Ty::Int(it))),
                        _ => Err(err(*line, "cannot negate this type")),
                    },
                    ast::UnOp::Not => {
                        match xty {
                            Ty::Bool => Ok((Ex::Not(Box::new(xe)), Ty::Bool)),
                            _ => Err(err(*line, "NOT needs BOOL")),
                        }
                    }
                }
            }
            E::Binary(op, a, b, line) => self.lower_binary(*op, a, b, cx, *line),
            E::Call { callee, args, line } => {
                self.lower_call(callee, args, cx, *line)
            }
            E::StructLit(_, line) => Err(err(
                *line,
                "struct literals are only valid as assignment values",
            )),
        }
    }

    /// Lower a struct literal against a known struct type.
    fn lower_struct_lit(
        &mut self,
        sid: usize,
        fields: &[(String, ast::Expr)],
        cx: &mut BodyCx,
        line: u32,
    ) -> Result<Ex, SemaError> {
        let def = self.unit.structs[sid].clone();
        let mut out = Vec::new();
        for (name, e) in fields {
            let idx = def
                .fields
                .iter()
                .position(|f| f.name.eq_ignore_ascii_case(name))
                .ok_or_else(|| {
                    err(line, format!("{} has no field {name}", def.name))
                })?;
            let (ex, ety) = self.lower_expr(e, cx)?;
            let ex = coerce(ex, &ety, &def.fields[idx].ty, line)?;
            out.push((idx as u16, ex));
        }
        Ok(Ex::StructLit(sid, out))
    }

    /// Flatten a multi-dim index into one bounds-checked flat index.
    fn flat_index(
        &mut self,
        idxs: &[ast::Expr],
        dims: &Arc<Vec<(i64, i64)>>,
        cx: &mut BodyCx,
        line: u32,
    ) -> Result<(Ex, u32), SemaError> {
        if idxs.len() != dims.len() {
            return Err(err(
                line,
                format!("expected {} subscripts, got {}", dims.len(), idxs.len()),
            ));
        }
        let total: i64 =
            dims.iter().map(|(lo, hi)| hi - lo + 1).product();
        let mut flat: Option<Ex> = None;
        for (i, (lo, hi)) in dims.iter().enumerate() {
            let (ie, ity) = self.lower_expr(&idxs[i], cx)?;
            expect_int(&ity, line)?;
            let extent = hi - lo + 1;
            // (ie - lo)
            let adjusted = if *lo == 0 {
                ie
            } else {
                fold_arith(ArithOp::Sub, NumKind::Int, ie, Ex::KInt(*lo), line)
            };
            flat = Some(match flat {
                None => adjusted,
                Some(acc) => {
                    let scaled = fold_arith(
                        ArithOp::Mul,
                        NumKind::Int,
                        acc,
                        Ex::KInt(extent),
                        line,
                    );
                    fold_arith(ArithOp::Add, NumKind::Int, scaled, adjusted, line)
                }
            });
        }
        Ok((flat.unwrap(), total as u32))
    }

    fn lower_binary(
        &mut self,
        op: ast::BinOp,
        a: &ast::Expr,
        b: &ast::Expr,
        cx: &mut BodyCx,
        line: u32,
    ) -> Result<(Ex, Ty), SemaError> {
        use ast::BinOp as B;
        let (ae, aty) = self.lower_expr(a, cx)?;
        let (be, bty) = self.lower_expr(b, cx)?;
        match op {
            B::And | B::Or | B::Xor => {
                let bop = match op {
                    B::And => BoolOp::And,
                    B::Or => BoolOp::Or,
                    _ => BoolOp::Xor,
                };
                match (&aty, &bty) {
                    (Ty::Bool, Ty::Bool) => {
                        Ok((Ex::BoolB(bop, Box::new(ae), Box::new(be)), Ty::Bool))
                    }
                    (Ty::Int(it), Ty::Int(_)) => Ok((
                        Ex::IntB(bop, Box::new(ae), Box::new(be)),
                        Ty::Int(*it),
                    )),
                    _ => Err(err(line, "AND/OR/XOR need BOOL or integer operands")),
                }
            }
            B::Eq | B::Neq | B::Lt | B::Gt | B::Le | B::Ge => {
                let cop = match op {
                    B::Eq => CmpOp::Eq,
                    B::Neq => CmpOp::Neq,
                    B::Lt => CmpOp::Lt,
                    B::Gt => CmpOp::Gt,
                    B::Le => CmpOp::Le,
                    _ => CmpOp::Ge,
                };
                if aty == Ty::Bool && bty == Ty::Bool {
                    return Ok((
                        Ex::CmpBool(cop, Box::new(ae), Box::new(be)),
                        Ty::Bool,
                    ));
                }
                let (ae, be, kind, _) =
                    promote(ae, aty, be, bty, line)?;
                Ok((Ex::Cmp(cop, kind, Box::new(ae), Box::new(be)), Ty::Bool))
            }
            B::Add | B::Sub | B::Mul | B::Div | B::Mod | B::Pow => {
                let aop = match op {
                    B::Add => ArithOp::Add,
                    B::Sub => ArithOp::Sub,
                    B::Mul => ArithOp::Mul,
                    B::Div => ArithOp::Div,
                    B::Mod => ArithOp::Mod,
                    _ => ArithOp::Pow,
                };
                let (ae, be, kind, ty) = promote(ae, aty, be, bty, line)?;
                if aop == ArithOp::Mod && kind != NumKind::Int {
                    return Err(err(line, "MOD needs integer operands"));
                }
                Ok((fold_arith(aop, kind, ae, be, line), ty))
            }
        }
    }

    // ------------------------------------------------------- lvalues
    fn lower_lv(
        &mut self,
        e: &ast::Expr,
        cx: &mut BodyCx,
    ) -> Result<(Lv, Ty), SemaError> {
        use ast::Expr as E;
        match e {
            E::Name(n, line) => match cx.lookup(n) {
                Some(Binding::Slot(s, ty)) => Ok((Lv::Local(s), ty)),
                Some(Binding::Konst(_)) => {
                    Err(err(*line, format!("cannot assign to constant {n}")))
                }
                None => {
                    if let Some((i, ty)) = cx.self_field_index(n) {
                        let ty = ty.clone();
                        return Ok((Lv::SelfField(i), ty));
                    }
                    if let Some(g) = self
                        .unit
                        .globals
                        .iter()
                        .position(|gv| gv.name.eq_ignore_ascii_case(n))
                    {
                        let ty = self.unit.globals[g].ty.clone();
                        return Ok((Lv::Global(g as u16), ty));
                    }
                    Err(err(*line, format!("unknown name {n}")))
                }
            },
            E::Member(base, field, line) => {
                let (be, bty) = self.lower_expr(base, cx)?;
                match bty {
                    Ty::Struct(sid) => {
                        let sd = &self.unit.structs[sid];
                        let idx = sd
                            .fields
                            .iter()
                            .position(|f| f.name.eq_ignore_ascii_case(field))
                            .ok_or_else(|| {
                                err(*line, format!("{} has no field {field}", sd.name))
                            })?;
                        let fty = sd.fields[idx].ty.clone();
                        Ok((Lv::Field(Box::new(be), idx as u16), fty))
                    }
                    Ty::Fb(fbid) => {
                        let fd = &self.unit.fbs[fbid];
                        let idx = fd
                            .fields
                            .iter()
                            .position(|f| f.name.eq_ignore_ascii_case(field))
                            .ok_or_else(|| {
                                err(*line, format!("{} has no field {field}", fd.name))
                            })?;
                        let fty = fd.fields[idx].ty.clone();
                        Ok((Lv::FbField(Box::new(be), idx as u16), fty))
                    }
                    other => Err(err(
                        *line,
                        format!("cannot assign through {other:?}"),
                    )),
                }
            }
            E::Index(base, idxs, line) => {
                let (be, bty) = self.lower_expr(base, cx)?;
                match &bty {
                    Ty::Arr(elem, dims) => {
                        let (flat, len) = self.flat_index(idxs, dims, cx, *line)?;
                        let kind = elem_kind(elem, *line)?;
                        Ok((
                            Lv::Idx(Box::new(be), Box::new(flat), len, kind, *line),
                            (**elem).clone(),
                        ))
                    }
                    Ty::Ptr(elem) => {
                        if idxs.len() != 1 {
                            return Err(err(*line, "pointer index takes one subscript"));
                        }
                        let (ie, ity) = self.lower_expr(&idxs[0], cx)?;
                        expect_int(&ity, *line)?;
                        let pk = ptr_kind(elem, *line)?;
                        Ok((
                            Lv::PtrAt(Box::new(be), Some(Box::new(ie)), pk, *line),
                            (**elem).clone(),
                        ))
                    }
                    other => Err(err(*line, format!("indexing non-array {other:?}"))),
                }
            }
            E::Deref(base, line) => {
                let (be, bty) = self.lower_expr(base, cx)?;
                match bty {
                    Ty::Ptr(elem) => {
                        let pk = ptr_kind(&elem, *line)?;
                        Ok((Lv::PtrAt(Box::new(be), None, pk, *line), *elem))
                    }
                    other => Err(err(*line, format!("deref of non-pointer {other:?}"))),
                }
            }
            other => Err(err(other.line(), "not an assignable place")),
        }
    }

    // ---------------------------------------------------------- calls
    fn lower_call(
        &mut self,
        callee: &ast::Expr,
        args: &[ast::Arg],
        cx: &mut BodyCx,
        line: u32,
    ) -> Result<(Ex, Ty), SemaError> {
        match callee {
            ast::Expr::Name(n, _) => self.lower_named_call(n, args, cx, line),
            ast::Expr::Member(base, m, _) => {
                let (be, bty) = self.lower_expr(base, cx)?;
                let pos_args = self.positional(args, cx, line)?;
                match bty {
                    Ty::Fb(fbid) => {
                        let midx = self.unit.fbs[fbid]
                            .methods
                            .iter()
                            .position(|md| md.name.eq_ignore_ascii_case(m))
                            .ok_or_else(|| {
                                err(line, format!("FB {} has no method {m}", self.unit.fbs[fbid].name))
                            })?;
                        self.edges.push((cx.node, Node::Method(fbid, midx)));
                        let md = &self.unit.fbs[fbid].methods[midx];
                        let (args, ret) =
                            self.check_call_sig(md, pos_args, line)?;
                        Ok((
                            Ex::CallMethod(fbid, midx, Box::new(be), args),
                            ret,
                        ))
                    }
                    Ty::Iface(iid) => {
                        let mid = self.unit.ifaces[iid]
                            .methods
                            .iter()
                            .position(|mn| *mn == upper(m))
                            .ok_or_else(|| {
                                err(line, format!("interface {} has no method {m}", self.unit.ifaces[iid].name))
                            })?;
                        // Conservative recursion edges: any implementor.
                        let impls: Vec<(usize, usize)> = self
                            .unit
                            .fbs
                            .iter()
                            .enumerate()
                            .filter_map(|(fi, fb)| {
                                fb.vtables
                                    .get(iid)
                                    .and_then(|v| v.as_ref())
                                    .map(|v| (fi, v[mid]))
                            })
                            .collect();
                        for (fi, mi) in impls {
                            self.edges.push((cx.node, Node::Method(fi, mi)));
                        }
                        // Use the first implementor's signature as the
                        // canonical one (interface sigs are checked at
                        // vtable build time).
                        let sig_ret = self.iface_ret_ty(iid, mid);
                        let args = pos_args.into_iter().map(|(e, _)| e).collect();
                        Ok((
                            Ex::CallIface(iid, mid, Box::new(be), args, line),
                            sig_ret,
                        ))
                    }
                    other => Err(err(
                        line,
                        format!("method call on non-FB/interface {other:?}"),
                    )),
                }
            }
            other => Err(err(other.line(), "uncallable expression")),
        }
    }

    fn iface_ret_ty(&self, iid: usize, mid: usize) -> Ty {
        for fb in &self.unit.fbs {
            if let Some(Some(v)) = fb.vtables.get(iid).map(|x| x.as_ref()) {
                let md = &fb.methods[v[mid]];
                if md.has_ret {
                    return md.slots[0].ty.clone();
                }
                return Ty::Bool;
            }
        }
        Ty::Bool
    }

    fn positional(
        &mut self,
        args: &[ast::Arg],
        cx: &mut BodyCx,
        line: u32,
    ) -> Result<Vec<(Ex, Ty)>, SemaError> {
        let mut out = Vec::new();
        for a in args {
            if a.is_output {
                return Err(err(line, "output binding only valid on FB invocation"));
            }
            let (e, t) = self.lower_expr(&a.value, cx)?;
            out.push((e, t));
        }
        Ok(out)
    }

    fn check_call_sig(
        &self,
        fd: &FuncDef,
        args: Vec<(Ex, Ty)>,
        line: u32,
    ) -> Result<(Vec<Ex>, Ty), SemaError> {
        let want = fd.n_inputs + fd.n_inouts;
        if args.len() != want {
            return Err(err(
                line,
                format!("{} expects {} arguments, got {}", fd.name, want, args.len()),
            ));
        }
        let mut out = Vec::new();
        for (i, (e, t)) in args.into_iter().enumerate() {
            let pty = &fd.slots[1 + i].ty;
            out.push(coerce(e, &t, pty, line)?);
        }
        let ret = if fd.has_ret { fd.slots[0].ty.clone() } else { Ty::Bool };
        Ok((out, ret))
    }

    fn lower_named_call(
        &mut self,
        name: &str,
        args: &[ast::Arg],
        cx: &mut BodyCx,
        line: u32,
    ) -> Result<(Ex, Ty), SemaError> {
        let u = upper(name);
        // ADR / SIZEOF are special forms.
        if u == "ADR" {
            if args.len() != 1 {
                return Err(err(line, "ADR takes one argument"));
            }
            let (lv, ty) = self.lower_lv(&args[0].value, cx)?;
            let (elem, base_is_arr) = match &ty {
                Ty::Arr(e, _) => ((**e).clone(), true),
                Ty::Real | Ty::LReal | Ty::Int(_) => (ty.clone(), false),
                _ => return Err(err(line, "ADR needs an array or array element")),
            };
            // ADR(arr) points at element 0; ADR(arr[i]) / ADR(p[i]) at
            // element i (pointer arithmetic).
            if !base_is_arr && !matches!(lv, Lv::Idx(..) | Lv::PtrAt(..)) {
                return Err(err(
                    line,
                    "ADR of scalars is only supported for array elements \
                     (PLC static-allocation semantics)",
                ));
            }
            let pk = ptr_kind(&elem, line)?;
            return Ok((
                Ex::Adr(Box::new(lv), pk),
                Ty::Ptr(Box::new(elem)),
            ));
        }
        if u == "SIZEOF" {
            if args.len() != 1 {
                return Err(err(line, "SIZEOF takes one argument"));
            }
            // Type name or expression.
            if let ast::Expr::Name(n, _) = &args[0].value {
                if let Ok(ty) = self.resolve_type(
                    &ast::TypeRef::Named(n.clone()),
                    &HashMap::new(),
                    line,
                ) {
                    let sz = ty.byte_size(&self.unit) as i64;
                    return Ok((Ex::KInt(sz), Ty::Int(IntTy::Udint)));
                }
            }
            let (_, ty) = self.lower_expr(&args[0].value, cx)?;
            let sz = ty.byte_size(&self.unit) as i64;
            return Ok((Ex::KInt(sz), Ty::Int(IntTy::Udint)));
        }
        // Conversion functions: A_TO_B.
        if let Some((ex, ty)) = self.try_conversion(&u, args, cx, line)? {
            return Ok((ex, ty));
        }
        // Intrinsics.
        if let Some((ex, ty)) = self.try_intrinsic(&u, args, cx, line)? {
            return Ok((ex, ty));
        }
        // User function.
        if let Some(&fid) = self.func_ids.get(&u) {
            self.edges.push((cx.node, Node::Func(fid)));
            let pos = self.positional(args, cx, line)?;
            let fd = self.unit.funcs[fid].clone();
            // Inout params must be arrays/structs; they share handles —
            // enforced by FuncDef layout (inputs first, inouts after).
            let (args, ret) = self.check_call_sig(&fd, pos, line)?;
            return Ok((Ex::CallFn(fid, args), ret));
        }
        Err(err(line, format!("unknown function {name}")))
    }

    fn try_conversion(
        &mut self,
        u: &str,
        args: &[ast::Arg],
        cx: &mut BodyCx,
        line: u32,
    ) -> Result<Option<(Ex, Ty)>, SemaError> {
        let Some(pos) = u.find("_TO_") else { return Ok(None) };
        let (from, to) = (&u[..pos], &u[pos + 4..]);
        let is_ty = |s: &str| {
            s == "REAL" || s == "LREAL" || Self::int_ty(s).is_some() || s == "BOOL"
        };
        if !is_ty(from) || !is_ty(to) {
            return Ok(None);
        }
        if args.len() != 1 {
            return Err(err(line, format!("{u} takes one argument")));
        }
        let (xe, xty) = self.lower_expr(&args[0].value, cx)?;
        // From-type must match the argument (loosely: int widths
        // interchangeable).
        let ok = match (&xty, from) {
            (Ty::Real, "REAL") => true,
            (Ty::LReal, "LREAL") => true,
            (Ty::Bool, "BOOL") => true,
            (Ty::Int(_), f) => Self::int_ty(f).is_some(),
            _ => false,
        };
        if !ok {
            return Err(err(line, format!("{u}: argument is {xty:?}")));
        }
        let (ex, ty) = match (from, to) {
            (_, "REAL") if Self::int_ty(from).is_some() => {
                (Ex::IntToF32(Box::new(xe)), Ty::Real)
            }
            (_, "LREAL") if Self::int_ty(from).is_some() => {
                (Ex::IntToF64(Box::new(xe)), Ty::LReal)
            }
            ("REAL", "LREAL") => (Ex::F32ToF64(Box::new(xe)), Ty::LReal),
            ("LREAL", "REAL") => (Ex::F64ToF32(Box::new(xe)), Ty::Real),
            ("REAL", t) if Self::int_ty(t).is_some() => {
                let it = Self::int_ty(t).unwrap();
                (Ex::F32ToInt(Box::new(xe), it), Ty::Int(it))
            }
            ("LREAL", t) if Self::int_ty(t).is_some() => {
                let it = Self::int_ty(t).unwrap();
                (Ex::F64ToInt(Box::new(xe), it), Ty::Int(it))
            }
            ("BOOL", t) if Self::int_ty(t).is_some() => {
                let it = Self::int_ty(t).unwrap();
                (Ex::BoolToInt(Box::new(xe)), Ty::Int(it))
            }
            (f, t) if Self::int_ty(f).is_some() && Self::int_ty(t).is_some() => {
                let it = Self::int_ty(t).unwrap();
                (Ex::IntNarrow(Box::new(xe), it), Ty::Int(it))
            }
            _ => return Err(err(line, format!("unsupported conversion {u}"))),
        };
        Ok(Some((ex, ty)))
    }

    fn try_intrinsic(
        &mut self,
        u: &str,
        args: &[ast::Arg],
        cx: &mut BodyCx,
        line: u32,
    ) -> Result<Option<(Ex, Ty)>, SemaError> {
        let b = match u {
            "ABS" => Builtin::Abs,
            "SQRT" => Builtin::Sqrt,
            "EXP" => Builtin::Exp,
            "LN" => Builtin::Ln,
            "LOG" => Builtin::Log,
            "SIN" => Builtin::Sin,
            "COS" => Builtin::Cos,
            "TAN" => Builtin::Tan,
            "ATAN" => Builtin::Atan,
            "MIN" => Builtin::Min,
            "MAX" => Builtin::Max,
            "LIMIT" => Builtin::Limit,
            "TRUNC" => Builtin::Trunc,
            "FLOOR" => Builtin::Floor,
            "BINARR" => Builtin::BinArr,
            "ARRBIN" => Builtin::ArrBin,
            _ => return Ok(None),
        };
        let pos = self.positional(args, cx, line)?;
        match b {
            Builtin::BinArr | Builtin::ArrBin => {
                if pos.len() != 3 {
                    return Err(err(line, format!("{u} takes (file, bytes, ptr)")));
                }
                let mut it = pos.into_iter();
                let (fe, fty) = it.next().unwrap();
                let (be, bty) = it.next().unwrap();
                let (pe, pty) = it.next().unwrap();
                if fty != Ty::Str {
                    return Err(err(line, format!("{u}: first arg must be STRING")));
                }
                expect_int(&bty, line)?;
                if !matches!(pty, Ty::Ptr(_)) {
                    return Err(err(line, format!("{u}: third arg must be a pointer")));
                }
                Ok(Some((
                    Ex::Intrinsic(b, NumKind::Int, vec![fe, be, pe], line),
                    Ty::Bool,
                )))
            }
            Builtin::Min | Builtin::Max => {
                if pos.len() != 2 {
                    return Err(err(line, format!("{u} takes two arguments")));
                }
                let mut it = pos.into_iter();
                let (ae, aty) = it.next().unwrap();
                let (be, bty) = it.next().unwrap();
                let (ae, be, kind, ty) = promote(ae, aty, be, bty, line)?;
                Ok(Some((Ex::Intrinsic(b, kind, vec![ae, be], line), ty)))
            }
            Builtin::Limit => {
                if pos.len() != 3 {
                    return Err(err(line, "LIMIT takes (min, x, max)"));
                }
                let tys: Vec<Ty> = pos.iter().map(|(_, t)| t.clone()).collect();
                let kind = if tys.iter().any(|t| *t == Ty::LReal) {
                    NumKind::F64
                } else if tys.iter().any(|t| *t == Ty::Real) {
                    NumKind::F32
                } else {
                    NumKind::Int
                };
                let target = match kind {
                    NumKind::F32 => Ty::Real,
                    NumKind::F64 => Ty::LReal,
                    NumKind::Int => Ty::Int(IntTy::Dint),
                };
                let mut exs = Vec::new();
                for (e, t) in pos {
                    exs.push(coerce(e, &t, &target, line)?);
                }
                Ok(Some((Ex::Intrinsic(b, kind, exs, line), target)))
            }
            Builtin::Trunc | Builtin::Floor => {
                if pos.len() != 1 {
                    return Err(err(line, format!("{u} takes one argument")));
                }
                let (ae, aty) = pos.into_iter().next().unwrap();
                let kind = match aty {
                    Ty::Real => NumKind::F32,
                    Ty::LReal => NumKind::F64,
                    _ => return Err(err(line, format!("{u} needs REAL/LREAL"))),
                };
                Ok(Some((
                    Ex::Intrinsic(b, kind, vec![ae], line),
                    Ty::Int(IntTy::Dint),
                )))
            }
            _ => {
                if pos.len() != 1 {
                    return Err(err(line, format!("{u} takes one argument")));
                }
                let (ae, aty) = pos.into_iter().next().unwrap();
                let kind = match aty {
                    Ty::Real => NumKind::F32,
                    Ty::LReal => NumKind::F64,
                    Ty::Int(_) if b == Builtin::Abs => NumKind::Int,
                    Ty::Int(_) => {
                        // transcendentals promote int to REAL
                        return Ok(Some((
                            Ex::Intrinsic(
                                b,
                                NumKind::F32,
                                vec![Ex::IntToF32(Box::new(ae))],
                                line,
                            ),
                            Ty::Real,
                        )));
                    }
                    _ => return Err(err(line, format!("{u} needs a numeric argument"))),
                };
                let ty = match kind {
                    NumKind::F32 => Ty::Real,
                    NumKind::F64 => Ty::LReal,
                    NumKind::Int => Ty::Int(IntTy::Dint),
                };
                Ok(Some((Ex::Intrinsic(b, kind, vec![ae], line), ty)))
            }
        }
    }

    // ------------------------------------------------ recursion check
    fn check_recursion(&self) -> Result<(), SemaError> {
        use std::collections::HashSet;
        let mut adj: HashMap<Node, Vec<Node>> = HashMap::new();
        for (a, b) in &self.edges {
            adj.entry(*a).or_default().push(*b);
        }
        // Iterative DFS cycle detection (white/grey/black).
        let mut color: HashMap<Node, u8> = HashMap::new();
        for &start in adj.keys() {
            if color.get(&start).copied().unwrap_or(0) != 0 {
                continue;
            }
            let mut stack = vec![(start, 0usize)];
            color.insert(start, 1);
            while let Some(&mut (n, ref mut i)) = stack.last_mut() {
                let next = adj.get(&n).and_then(|v| v.get(*i)).copied();
                *i += 1;
                match next {
                    Some(m) => match color.get(&m).copied().unwrap_or(0) {
                        0 => {
                            color.insert(m, 1);
                            stack.push((m, 0));
                        }
                        1 => {
                            return Err(err(
                                0,
                                format!(
                                    "recursion detected involving {} \
                                     (IEC 61131-3 forbids recursive POU calls)",
                                    self.node_name(m)
                                ),
                            ));
                        }
                        _ => {}
                    },
                    None => {
                        color.insert(n, 2);
                        stack.pop();
                    }
                }
            }
        }
        let _ = HashSet::<Node>::new();
        Ok(())
    }

    fn node_name(&self, n: Node) -> String {
        match n {
            Node::Func(i) => self.unit.funcs[i].name.clone(),
            Node::Method(f, m) => format!(
                "{}.{}",
                self.unit.fbs[f].name, self.unit.fbs[f].methods[m].name
            ),
            Node::FbBody(f) => self.unit.fbs[f].name.clone(),
            Node::Program(p) => self.ast.programs[p].name.clone(),
        }
    }
}

// ------------------------------------------------------- free helpers
fn const_f64(c: Const) -> f64 {
    match c {
        Const::Int(v) => v as f64,
        Const::Real(v) => v,
        Const::Bool(b) => b as i64 as f64,
    }
}

fn const_i64(c: Const) -> i64 {
    match c {
        Const::Int(v) => v,
        Const::Real(v) => v as i64,
        Const::Bool(b) => b as i64,
    }
}

fn const_to_ex(c: Const) -> (Ex, Ty) {
    match c {
        Const::Int(v) => (Ex::KInt(v), Ty::Int(IntTy::Dint)),
        Const::Real(v) => (Ex::KReal(v as f32), Ty::Real),
        Const::Bool(b) => (Ex::KBool(b), Ty::Bool),
    }
}

fn const_bin(op: ast::BinOp, a: Const, b: Const, line: u32) -> Result<Const, SemaError> {
    use ast::BinOp as B;
    let both_int = matches!((a, b), (Const::Int(_), Const::Int(_)));
    Ok(match op {
        B::Add | B::Sub | B::Mul | B::Div | B::Mod => {
            if both_int {
                let (x, y) = (const_i64(a), const_i64(b));
                Const::Int(match op {
                    B::Add => x + y,
                    B::Sub => x - y,
                    B::Mul => x * y,
                    B::Div => {
                        if y == 0 {
                            return Err(err(line, "constant division by zero"));
                        }
                        x / y
                    }
                    _ => {
                        if y == 0 {
                            return Err(err(line, "constant MOD by zero"));
                        }
                        x % y
                    }
                })
            } else {
                let (x, y) = (const_f64(a), const_f64(b));
                Const::Real(match op {
                    B::Add => x + y,
                    B::Sub => x - y,
                    B::Mul => x * y,
                    B::Div => x / y,
                    _ => return Err(err(line, "MOD needs integers")),
                })
            }
        }
        B::Eq => Const::Bool(const_f64(a) == const_f64(b)),
        B::Neq => Const::Bool(const_f64(a) != const_f64(b)),
        B::Lt => Const::Bool(const_f64(a) < const_f64(b)),
        B::Gt => Const::Bool(const_f64(a) > const_f64(b)),
        B::Le => Const::Bool(const_f64(a) <= const_f64(b)),
        B::Ge => Const::Bool(const_f64(a) >= const_f64(b)),
        B::And | B::Or | B::Xor => match (a, b) {
            (Const::Bool(x), Const::Bool(y)) => Const::Bool(match op {
                B::And => x && y,
                B::Or => x || y,
                _ => x ^ y,
            }),
            _ => return Err(err(line, "boolean constant expected")),
        },
        B::Pow => Const::Real(const_f64(a).powf(const_f64(b))),
    })
}

fn expect_bool(ty: &Ty, line: u32) -> Result<(), SemaError> {
    if *ty == Ty::Bool {
        Ok(())
    } else {
        Err(err(line, format!("expected BOOL, got {ty:?}")))
    }
}

fn expect_int(ty: &Ty, line: u32) -> Result<(), SemaError> {
    if matches!(ty, Ty::Int(_)) {
        Ok(())
    } else {
        Err(err(line, format!("expected an integer, got {ty:?}")))
    }
}

fn elem_kind(ty: &Ty, line: u32) -> Result<ElemKind, SemaError> {
    Ok(match ty {
        Ty::Real => ElemKind::F32,
        Ty::LReal => ElemKind::F64,
        Ty::Int(_) | Ty::Bool => ElemKind::Int,
        Ty::Iface(_) => ElemKind::Ref,
        other => return Err(err(line, format!("unsupported array element {other:?}"))),
    })
}

fn ptr_kind(ty: &Ty, line: u32) -> Result<PtrKind, SemaError> {
    Ok(match ty {
        Ty::Real => PtrKind::F32,
        Ty::LReal => PtrKind::F64,
        Ty::Int(_) => PtrKind::Int,
        other => return Err(err(line, format!("unsupported pointer element {other:?}"))),
    })
}

/// Implicit numeric promotion for mixed operands (widening only).
fn promote(
    ae: Ex,
    aty: Ty,
    be: Ex,
    bty: Ty,
    line: u32,
) -> Result<(Ex, Ex, NumKind, Ty), SemaError> {
    match (&aty, &bty) {
        (Ty::Int(it), Ty::Int(_)) => Ok((ae, be, NumKind::Int, Ty::Int(*it))),
        (Ty::Real, Ty::Real) => Ok((ae, be, NumKind::F32, Ty::Real)),
        (Ty::LReal, Ty::LReal) => Ok((ae, be, NumKind::F64, Ty::LReal)),
        (Ty::Int(_), Ty::Real) => {
            Ok((Ex::IntToF32(Box::new(ae)), be, NumKind::F32, Ty::Real))
        }
        (Ty::Real, Ty::Int(_)) => {
            Ok((ae, Ex::IntToF32(Box::new(be)), NumKind::F32, Ty::Real))
        }
        (Ty::Int(_), Ty::LReal) => {
            Ok((Ex::IntToF64(Box::new(ae)), be, NumKind::F64, Ty::LReal))
        }
        (Ty::LReal, Ty::Int(_)) => {
            Ok((ae, Ex::IntToF64(Box::new(be)), NumKind::F64, Ty::LReal))
        }
        (Ty::Real, Ty::LReal) => {
            Ok((Ex::F32ToF64(Box::new(ae)), be, NumKind::F64, Ty::LReal))
        }
        (Ty::LReal, Ty::Real) => {
            Ok((ae, Ex::F32ToF64(Box::new(be)), NumKind::F64, Ty::LReal))
        }
        _ => Err(err(
            line,
            format!("type mismatch: {aty:?} vs {bty:?}"),
        )),
    }
}

/// Implicit assignment coercion (widening only; pointers must match).
fn coerce(e: Ex, from: &Ty, to: &Ty, line: u32) -> Result<Ex, SemaError> {
    if from == to {
        return Ok(e);
    }
    match (from, to) {
        (Ty::Int(_), Ty::Int(_)) => Ok(e), // same repr; width on convert only
        (Ty::Int(_), Ty::Real) => Ok(Ex::IntToF32(Box::new(e))),
        (Ty::Int(_), Ty::LReal) => Ok(Ex::IntToF64(Box::new(e))),
        (Ty::Real, Ty::LReal) => Ok(Ex::F32ToF64(Box::new(e))),
        (Ty::Ptr(_), Ty::Ptr(_)) if from == to => Ok(e),
        // NULL literal assigns to any pointer/interface.
        (Ty::Ptr(_), Ty::Iface(_)) => match e {
            Ex::KNull => Ok(e),
            _ => Err(err(line, format!("cannot assign {from:?} to {to:?}"))),
        },
        (Ty::Ptr(a), Ty::Ptr(b)) if a == b => Ok(e),
        (Ty::Fb(fid), Ty::Iface(iid)) => {
            // FB reference into interface variable — requires vtable;
            // checked at lowering by the caller having built vtables.
            let _ = (fid, iid);
            Ok(e)
        }
        (Ty::Iface(a), Ty::Iface(b)) if a == b => Ok(e),
        _ => Err(err(line, format!("cannot assign {from:?} to {to:?}"))),
    }
}

/// Constant-fold integer arithmetic where possible.
fn fold_arith(op: ArithOp, kind: NumKind, a: Ex, b: Ex, line: u32) -> Ex {
    if kind == NumKind::Int {
        if let (Ex::KInt(x), Ex::KInt(y)) = (&a, &b) {
            let v = match op {
                ArithOp::Add => x.checked_add(*y),
                ArithOp::Sub => x.checked_sub(*y),
                ArithOp::Mul => x.checked_mul(*y),
                ArithOp::Div if *y != 0 => Some(x / y),
                ArithOp::Mod if *y != 0 => Some(x % y),
                _ => None,
            };
            if let Some(v) = v {
                return Ex::KInt(v);
            }
        }
        // x + 0 / x * 1 identities (index math cleanup)
        if op == ArithOp::Add {
            if let Ex::KInt(0) = b {
                return a;
            }
            if let Ex::KInt(0) = a {
                return b;
            }
        }
        if op == ArithOp::Mul {
            if let Ex::KInt(1) = b {
                return a;
            }
        }
    }
    Ex::Arith(op, kind, Box::new(a), Box::new(b), line)
}
