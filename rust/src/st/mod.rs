//! IEC 61131-3 Structured Text substrate.
//!
//! The Codesys-runtime substitute the paper's benchmarks run on: a
//! lexer, parser, semantic checker and **two execution tiers** — the
//! tree-walking [`Interp`] (the §5.4 vendor-runtime reference oracle)
//! and the register-bytecode [`Vm`] ([`bytecode`] + [`vm`], the fast
//! tier serving `StBackend`) — for the ST subset that the ICSML
//! framework (and realistic PLC control code) needs, with the
//! standard's restrictions *enforced*:
//!
//! * **No recursion** (IEC 61131-3 forbids it so maximum program memory
//!   is computable): [`sema`] rejects call-graph cycles, including
//!   FB-method cycles.
//! * **No dynamic memory**: all arrays have compile-time bounds; there
//!   is no allocation construct.
//! * **Call-by-value `VAR_INPUT`**: array/struct arguments are deep
//!   copied at every call, and the copy bytes are metered — reproducing
//!   the duplication cost the paper's `dataMem` abstraction avoids.
//! * **No first-class functions**: functions are not values.
//!
//! Execution meters abstract instruction counts ([`cost::Meter`]) which
//! [`crate::plc`]'s hardware profiles convert to per-device CPU time —
//! that is how the paper's WAGO-PFC100 / BeagleBone-Black numbers are
//! modeled (DESIGN.md §2).

pub mod ast;
pub mod builtins;
pub mod bytecode;
pub mod cost;
pub mod disasm;
pub mod host;
pub mod interp;
pub mod ir;
pub mod lexer;
pub mod lower;
pub mod parser;
pub mod sema;
pub mod tasks;
pub mod value;
pub mod vm;

pub use bytecode::FusionConfig;
pub use cost::Meter;
pub use host::{FbInstance, Host, HostImage};
pub use interp::{Interp, RuntimeError};
pub use lexer::{lex, LexError, Token, TokenKind};
pub use parser::{parse, ParseError};
pub use sema::SemaError;
pub use tasks::{
    TaskDef, TaskModel, TaskRuntime, TaskScheduler, TickReport, Trigger,
};
pub use value::Value;
pub use vm::Vm;

/// Compile ST source text to an executable [`ir::Unit`].
///
/// Runs the full pipeline: lex → parse → semantic check (types,
/// recursion ban, const bounds) → lowering to the slot-resolved IR.
pub fn compile(source: &str) -> Result<ir::Unit, CompileError> {
    let tokens = lex(source).map_err(CompileError::Lex)?;
    let ast = parse(&tokens).map_err(CompileError::Parse)?;
    lower::lower(&ast).map_err(CompileError::Sema)
}

/// Any front-end failure, with source position context.
#[derive(Debug)]
pub enum CompileError {
    Lex(LexError),
    Parse(ParseError),
    Sema(SemaError),
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::Lex(e) => write!(f, "lex error: {e}"),
            CompileError::Parse(e) => write!(f, "parse error: {e}"),
            CompileError::Sema(e) => write!(f, "semantic error: {e}"),
        }
    }
}

impl std::error::Error for CompileError {}
