//! Semantic-error type shared by the lowering pass (the checker itself
//! lives in [`super::lower`]; property-style checks of its behaviour are
//! in `rust/tests/st_sema.rs`).

/// A semantic error with source-line context.
#[derive(Debug, Clone)]
pub struct SemaError {
    pub line: u32,
    pub message: String,
}

impl std::fmt::Display for SemaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for SemaError {}
