//! Parse tree for the ST subset (names unresolved; see [`super::lower`]
//! for the slot-resolved executable IR).

/// A compilation unit: every top-level declaration in one source text.
#[derive(Debug, Default, Clone)]
pub struct File {
    pub types: Vec<TypeDecl>,
    pub interfaces: Vec<InterfaceDecl>,
    pub functions: Vec<PouDecl>,
    pub function_blocks: Vec<FbDecl>,
    pub programs: Vec<PouDecl>,
    pub globals: Vec<VarBlock>,
    pub configurations: Vec<ConfigDecl>,
}

/// `CONFIGURATION name ... END_CONFIGURATION` — the IEC 61131-3 §2.7
/// deployment unit: resources, their tasks, and program-instance
/// bindings.
#[derive(Debug, Clone)]
pub struct ConfigDecl {
    pub name: String,
    pub resources: Vec<ResourceDecl>,
    pub line: u32,
}

/// `RESOURCE name ON processor ... END_RESOURCE` — one processing
/// unit holding TASK declarations and program instances.
#[derive(Debug, Clone)]
pub struct ResourceDecl {
    pub name: String,
    /// Processor/target identifier after `ON` (uninterpreted).
    pub on: String,
    pub tasks: Vec<TaskDecl>,
    pub programs: Vec<ProgBind>,
    pub line: u32,
}

/// `TASK name (INTERVAL := T#10ms, PRIORITY := 1);` or
/// `TASK name (SINGLE := trigger, PRIORITY := 1);`
#[derive(Debug, Clone)]
pub struct TaskDecl {
    pub name: String,
    /// Cyclic interval literal text (from `T#...`/`TIME#...`), if any.
    pub interval: Option<String>,
    /// `SINGLE := <global BOOL>` trigger variable name, if any.
    pub single: Option<String>,
    /// `PRIORITY := n` (constant expression; 0 = most urgent).
    pub priority: Option<Expr>,
    pub line: u32,
}

/// `PROGRAM inst WITH task : Type;` (WITH is optional: an unbound
/// instance freewheels at lowest priority).
#[derive(Debug, Clone)]
pub struct ProgBind {
    /// Program-instance name.
    pub name: String,
    /// Task the instance is bound to, if any.
    pub task: Option<String>,
    /// PROGRAM type the instance is of.
    pub program_type: String,
    pub line: u32,
}

/// `TYPE name : STRUCT ... END_STRUCT END_TYPE`
#[derive(Debug, Clone)]
pub struct TypeDecl {
    pub name: String,
    pub fields: Vec<VarDecl>,
    pub line: u32,
}

/// `INTERFACE name ... END_INTERFACE` — method signatures only.
#[derive(Debug, Clone)]
pub struct InterfaceDecl {
    pub name: String,
    pub methods: Vec<MethodSig>,
    pub line: u32,
}

#[derive(Debug, Clone)]
pub struct MethodSig {
    pub name: String,
    pub ret: Option<TypeRef>,
    pub inputs: Vec<VarDecl>,
    pub line: u32,
}

/// FUNCTION or PROGRAM (same surface shape; functions have return types).
#[derive(Debug, Clone)]
pub struct PouDecl {
    pub name: String,
    pub ret: Option<TypeRef>,
    pub blocks: Vec<VarBlock>,
    pub body: Vec<Stmt>,
    pub line: u32,
}

/// `FUNCTION_BLOCK name IMPLEMENTS i1, i2 ... END_FUNCTION_BLOCK`
#[derive(Debug, Clone)]
pub struct FbDecl {
    pub name: String,
    pub implements: Vec<String>,
    pub blocks: Vec<VarBlock>,
    pub methods: Vec<PouDecl>,
    /// Optional FB body (runs on `inst(...)` invocation).
    pub body: Vec<Stmt>,
    pub line: u32,
}

/// One VAR section with its kind.
#[derive(Debug, Clone)]
pub struct VarBlock {
    pub kind: VarKind,
    pub constant: bool,
    pub decls: Vec<VarDecl>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VarKind {
    Input,
    Output,
    InOut,
    Local,
    Global,
}

#[derive(Debug, Clone)]
pub struct VarDecl {
    pub name: String,
    pub ty: TypeRef,
    pub init: Option<Initializer>,
    pub line: u32,
}

/// Unresolved type reference.
#[derive(Debug, Clone)]
pub enum TypeRef {
    /// Elementary or user type by (case-preserved) name.
    Named(String),
    /// `ARRAY [lo..hi, ...] OF elem` — bounds are const expressions.
    Array(Vec<(Expr, Expr)>, Box<TypeRef>),
    /// `POINTER TO elem`
    Pointer(Box<TypeRef>),
    /// `STRING` (fixed default length)
    StringTy,
}

#[derive(Debug, Clone)]
pub enum Initializer {
    Expr(Expr),
    /// `[a, b, c]` array initializer (with `n(x)` repetition support).
    Array(Vec<(Option<Expr>, Expr)>),
    /// `(field := expr, ...)` struct initializer.
    Struct(Vec<(String, Expr)>),
}

#[derive(Debug, Clone)]
pub enum Stmt {
    Assign { target: Expr, value: Expr, line: u32 },
    If {
        arms: Vec<(Expr, Vec<Stmt>)>,
        else_body: Vec<Stmt>,
        line: u32,
    },
    Case {
        scrutinee: Expr,
        arms: Vec<(Vec<CaseLabel>, Vec<Stmt>)>,
        else_body: Vec<Stmt>,
        line: u32,
    },
    For {
        var: String,
        from: Expr,
        to: Expr,
        by: Option<Expr>,
        body: Vec<Stmt>,
        line: u32,
    },
    While { cond: Expr, body: Vec<Stmt>, line: u32 },
    Repeat { body: Vec<Stmt>, until: Expr, line: u32 },
    Exit { line: u32 },
    Continue { line: u32 },
    Return { line: u32 },
    /// Bare call (function, method, or FB invocation).
    Call { expr: Expr, line: u32 },
    Empty,
}

#[derive(Debug, Clone)]
pub enum CaseLabel {
    Single(Expr),
    Range(Expr, Expr),
}

#[derive(Debug, Clone)]
pub enum Expr {
    IntLit(i64),
    RealLit(f64),
    BoolLit(bool),
    StrLit(String),
    /// `TYPE#lit`
    TypedLit(String, String),
    NullLit,
    /// Bare name (variable / constant / enum-like).
    Name(String, u32),
    /// `base.field` (struct field, FB output, or method ref in calls).
    Member(Box<Expr>, String, u32),
    /// `base[i, j]`
    Index(Box<Expr>, Vec<Expr>, u32),
    /// `p^`
    Deref(Box<Expr>, u32),
    Unary(UnOp, Box<Expr>, u32),
    Binary(BinOp, Box<Expr>, Box<Expr>, u32),
    /// `callee(args)` — callee is Name (function) or Member (method /
    /// FB invocation). Args may be positional or named (`x := e`), plus
    /// output bindings (`y => v`).
    Call {
        callee: Box<Expr>,
        args: Vec<Arg>,
        line: u32,
    },
    /// `(field := expr, ...)` struct literal (assignment RHS only).
    StructLit(Vec<(String, Expr)>, u32),
}

impl Expr {
    pub fn line(&self) -> u32 {
        match self {
            Expr::Name(_, l)
            | Expr::Member(_, _, l)
            | Expr::Index(_, _, l)
            | Expr::Deref(_, l)
            | Expr::Unary(_, _, l)
            | Expr::Binary(_, _, _, l)
            | Expr::Call { line: l, .. } => *l,
            _ => 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Arg {
    pub name: Option<String>,
    /// `name => target` output binding (FB invocation outputs).
    pub is_output: bool,
    pub value: Expr,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnOp {
    Neg,
    Not,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Pow,
    And,
    Or,
    Xor,
    Eq,
    Neq,
    Lt,
    Gt,
    Le,
    Ge,
}
