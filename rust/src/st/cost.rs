//! Abstract instruction-cost metering.
//!
//! The interpreter counts architecture-independent operation classes;
//! [`crate::plc::profiles`] maps the counters to per-device CPU time
//! using cost vectors calibrated on the paper's published anchors
//! (DESIGN.md §9). This is how one ST execution yields *both* the
//! WAGO-PFC100 and the BeagleBone-Black timelines of Fig. 4.

/// Operation counters accumulated during interpretation.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Meter {
    /// Variable/array/pointer reads.
    pub loads: u64,
    /// Variable/array/pointer writes.
    pub stores: u64,
    /// f32/f64 add/sub.
    pub fp_add: u64,
    /// f32/f64 multiply.
    pub fp_mul: u64,
    /// f32/f64 divide.
    pub fp_div: u64,
    /// Transcendental calls (EXP, LN, SQRT, trig, POW).
    pub fp_trans: u64,
    /// Integer/bool ALU operations.
    pub int_ops: u64,
    /// Integer/bool comparisons.
    pub cmp: u64,
    /// Floating-point comparisons (expensive on non-pipelined VFP —
    /// the §6.2 reason the f32 IF-skip does not pay off).
    pub fp_cmp: u64,
    /// Taken control-flow decisions (if/case/loop back-edges).
    pub branches: u64,
    /// POU calls (functions, methods, FB bodies).
    pub calls: u64,
    /// Bytes copied by VAR_INPUT call-by-value + array/struct assigns.
    pub copy_bytes: u64,
    /// Int<->float conversions.
    pub converts: u64,
    /// File-I/O operations (BINARR/ARRBIN calls).
    pub io_calls: u64,
    /// Bytes moved through file I/O.
    pub io_bytes: u64,
}

impl Meter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total abstract operations (excludes copy/io byte counts).
    pub fn total_ops(&self) -> u64 {
        self.loads
            + self.stores
            + self.fp_add
            + self.fp_mul
            + self.fp_div
            + self.fp_trans
            + self.int_ops
            + self.cmp
            + self.fp_cmp
            + self.branches
            + self.calls
            + self.converts
    }

    /// Name the first counter differing from `other`, with both values
    /// (`None` when equal). The differential harnesses use this to
    /// report *which* op class a tier drifted on instead of dumping
    /// two 15-field structs.
    pub fn first_divergence(
        &self,
        other: &Meter,
    ) -> Option<(&'static str, u64, u64)> {
        let pairs = [
            ("loads", self.loads, other.loads),
            ("stores", self.stores, other.stores),
            ("fp_add", self.fp_add, other.fp_add),
            ("fp_mul", self.fp_mul, other.fp_mul),
            ("fp_div", self.fp_div, other.fp_div),
            ("fp_trans", self.fp_trans, other.fp_trans),
            ("int_ops", self.int_ops, other.int_ops),
            ("cmp", self.cmp, other.cmp),
            ("fp_cmp", self.fp_cmp, other.fp_cmp),
            ("branches", self.branches, other.branches),
            ("calls", self.calls, other.calls),
            ("copy_bytes", self.copy_bytes, other.copy_bytes),
            ("converts", self.converts, other.converts),
            ("io_calls", self.io_calls, other.io_calls),
            ("io_bytes", self.io_bytes, other.io_bytes),
        ];
        pairs.iter().find(|(_, a, b)| a != b).copied()
    }

    /// Counter delta `self - earlier` (panics if counters went backwards).
    pub fn since(&self, earlier: &Meter) -> Meter {
        Meter {
            loads: self.loads - earlier.loads,
            stores: self.stores - earlier.stores,
            fp_add: self.fp_add - earlier.fp_add,
            fp_mul: self.fp_mul - earlier.fp_mul,
            fp_div: self.fp_div - earlier.fp_div,
            fp_trans: self.fp_trans - earlier.fp_trans,
            int_ops: self.int_ops - earlier.int_ops,
            cmp: self.cmp - earlier.cmp,
            fp_cmp: self.fp_cmp - earlier.fp_cmp,
            branches: self.branches - earlier.branches,
            calls: self.calls - earlier.calls,
            copy_bytes: self.copy_bytes - earlier.copy_bytes,
            converts: self.converts - earlier.converts,
            io_calls: self.io_calls - earlier.io_calls,
            io_bytes: self.io_bytes - earlier.io_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn since_computes_delta() {
        let mut a = Meter::new();
        a.loads = 10;
        a.fp_mul = 4;
        let mut b = a.clone();
        b.loads = 25;
        b.fp_mul = 9;
        let d = b.since(&a);
        assert_eq!(d.loads, 15);
        assert_eq!(d.fp_mul, 5);
        assert_eq!(d.stores, 0);
    }

    #[test]
    fn total_ops_sums_op_classes() {
        let m = Meter { loads: 1, stores: 2, fp_add: 3, ..Meter::default() };
        assert_eq!(m.total_ops(), 6);
    }

    /// Every op-class counter participates in `total_ops`; the byte
    /// counters (copy/io) deliberately do not. A counter added to the
    /// struct but forgotten in `total_ops` would silently skew the
    /// ops/cycle figures in BENCH_st_vm.json.
    #[test]
    fn total_ops_counts_each_class_once_and_no_bytes() {
        let m = Meter {
            loads: 1,
            stores: 1,
            fp_add: 1,
            fp_mul: 1,
            fp_div: 1,
            fp_trans: 1,
            int_ops: 1,
            cmp: 1,
            fp_cmp: 1,
            branches: 1,
            calls: 1,
            converts: 1,
            copy_bytes: 1000,
            io_calls: 7,
            io_bytes: 1000,
        };
        // 12 op classes; io_calls is I/O accounting, not CPU ops.
        assert_eq!(m.total_ops(), 12);
    }

    #[test]
    fn since_full_delta_across_every_counter() {
        let a = Meter {
            loads: 10,
            stores: 9,
            fp_add: 8,
            fp_mul: 7,
            fp_div: 6,
            fp_trans: 5,
            int_ops: 4,
            cmp: 3,
            fp_cmp: 2,
            branches: 1,
            calls: 11,
            converts: 12,
            copy_bytes: 13,
            io_calls: 14,
            io_bytes: 15,
        };
        let mut b = a.clone();
        b.loads += 100;
        b.stores += 99;
        b.fp_add += 98;
        b.fp_mul += 97;
        b.fp_div += 96;
        b.fp_trans += 95;
        b.int_ops += 94;
        b.cmp += 93;
        b.fp_cmp += 92;
        b.branches += 91;
        b.calls += 90;
        b.converts += 89;
        b.copy_bytes += 88;
        b.io_calls += 87;
        b.io_bytes += 86;
        let d = b.since(&a);
        assert_eq!(
            (d.loads, d.stores, d.fp_add, d.fp_mul, d.fp_div),
            (100, 99, 98, 97, 96)
        );
        assert_eq!(
            (d.fp_trans, d.int_ops, d.cmp, d.fp_cmp, d.branches),
            (95, 94, 93, 92, 91)
        );
        assert_eq!((d.calls, d.converts), (90, 89));
        assert_eq!((d.copy_bytes, d.io_calls, d.io_bytes), (88, 87, 86));
        // since(self) is the zero delta; zero delta has no ops.
        assert_eq!(b.since(&b).total_ops(), 0);
    }

    #[test]
    fn first_divergence_names_the_counter() {
        let a = Meter { loads: 3, fp_mul: 2, ..Meter::default() };
        assert_eq!(a.first_divergence(&a), None);
        let mut b = a.clone();
        b.fp_mul = 5;
        assert_eq!(a.first_divergence(&b), Some(("fp_mul", 2, 5)));
        // Field order is the struct's: the first drifting counter wins.
        b.loads = 0;
        assert_eq!(a.first_divergence(&b), Some(("loads", 3, 0)));
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn since_panics_when_counters_go_backwards() {
        let mut a = Meter::new();
        a.loads = 5;
        let b = Meter::new();
        // b predates a: counters "went backwards".
        let _ = b.since(&a);
    }
}
