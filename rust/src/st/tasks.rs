//! IEC 61131-3 §2.7 task model: CONFIGURATION → RESOURCE → TASK.
//!
//! [`super::lower`] compiles a source `CONFIGURATION` block into a
//! [`TaskModel`] carried on the [`Unit`](super::ir::Unit); this module
//! executes it. [`TaskScheduler`] is a priority-driven cyclic
//! executive over *simulated* time: cyclic tasks release on their
//! `INTERVAL`, `SINGLE` tasks on a rising edge of a global BOOL, and
//! programs not bound to any task freewheel at the lowest priority.
//! Time is modeled, never wall clock — each activation's cost is the
//! task's [`Meter`] delta priced through a [`HwProfile`], so a
//! schedule replays bit-identically on the [`Interp`] oracle and the
//! bytecode [`Vm`] (the differential invariant extends per task:
//! `tests/st_tasks.rs`).
//!
//! Budget accounting reuses [`plc::ScanCycle`](crate::plc::ScanCycle):
//! every cyclic task owns one cycle ledger (period = its interval), so
//! overruns and accumulated time use the same arithmetic the serving
//! deadlines ([`Deadline::for_scan`](crate::serve::Deadline::for_scan))
//! are derived from. A due task is *skipped* — deterministically, with
//! a counter — when higher-priority work in the same release instant
//! has already consumed its whole interval; the highest-priority task
//! therefore can never skip.

#![deny(missing_docs)]

use crate::plc::{HwProfile, ScanCycle};

use super::cost::Meter;
use super::host::Host;
use super::interp::{Interp, RuntimeError};
use super::value::Value;
use super::vm::Vm;

// ---------------------------------------------------------------- model

/// The compiled §2.7 deployment model: one CONFIGURATION / RESOURCE
/// worth of tasks with their program-instance bindings, produced by
/// [`super::lower`] and carried on [`Unit::tasks`](super::ir::Unit).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskModel {
    /// CONFIGURATION name (case-preserved).
    pub config_name: String,
    /// RESOURCE name.
    pub resource_name: String,
    /// Processor identifier after `ON` (uninterpreted).
    pub processor: String,
    /// Tasks in declaration order (synthetic freewheeling tasks for
    /// unbound program instances come last).
    pub tasks: Vec<TaskDef>,
}

impl TaskModel {
    /// Find a task by (case-insensitive) name.
    pub fn find_task(&self, name: &str) -> Option<usize> {
        self.tasks
            .iter()
            .position(|t| t.name.eq_ignore_ascii_case(name))
    }
}

/// One task: trigger, priority, and the program instances it runs (in
/// binding order).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskDef {
    /// Task name.
    pub name: String,
    /// What releases the task.
    pub trigger: Trigger,
    /// IEC priority: 0 is the most urgent. Synthetic freewheeling
    /// tasks use `u32::MAX`.
    pub priority: u32,
    /// Bound program instances, in declaration order.
    pub programs: Vec<ProgramBinding>,
}

/// Task release trigger.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trigger {
    /// `INTERVAL := T#..` — released every `interval_us` of simulated
    /// time (first release at t = 0).
    Cyclic {
        /// Release period in simulated microseconds (> 0).
        interval_us: u64,
    },
    /// `SINGLE := g` — released on a rising edge of global BOOL `g`
    /// (index into [`Unit::globals`](super::ir::Unit)).
    Single {
        /// Global slot of the trigger variable.
        global: usize,
    },
    /// No task association: runs every scheduler tick at the lowest
    /// priority (IEC's default for unbound program instances).
    Freewheeling,
}

/// A `PROGRAM inst WITH task : Type` binding, resolved to a program
/// definition index.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgramBinding {
    /// Instance name from the RESOURCE block.
    pub instance: String,
    /// Index into [`Unit::programs`](super::ir::Unit).
    pub program: usize,
}

// ------------------------------------------------------------ durations

/// Parse an IEC duration literal body (the text after `T#`/`TIME#`)
/// into microseconds. Accepts multi-component forms (`1s500ms`),
/// decimal components (`1.5s`), units `d`/`h`/`m`/`s`/`ms`/`us`, an
/// optional leading sign, and `_` digit separators. Returns `None` on
/// malformed input.
pub fn parse_duration_us(lit: &str) -> Option<i64> {
    let lit = lit.trim();
    let (neg, mut rest) = match lit.as_bytes().first()? {
        b'-' => (true, &lit[1..]),
        b'+' => (false, &lit[1..]),
        _ => (false, lit),
    };
    if rest.is_empty() {
        return None;
    }
    let mut total = 0.0f64;
    while !rest.is_empty() {
        let num_len = rest
            .bytes()
            .take_while(|c| c.is_ascii_digit() || *c == b'.' || *c == b'_')
            .count();
        if num_len == 0 {
            return None;
        }
        let num: f64 = rest[..num_len].replace('_', "").parse().ok()?;
        rest = &rest[num_len..];
        let unit_len = rest
            .bytes()
            .take_while(|c| c.is_ascii_alphabetic())
            .count();
        let unit_us = match rest[..unit_len].to_ascii_lowercase().as_str() {
            "d" => 86_400_000_000.0,
            "h" => 3_600_000_000.0,
            "m" => 60_000_000.0,
            "s" => 1_000_000.0,
            "ms" => 1_000.0,
            "us" => 1.0,
            _ => return None,
        };
        rest = &rest[unit_len..];
        total += num * unit_us;
    }
    let us = total.round();
    if !us.is_finite() || us.abs() > i64::MAX as f64 {
        return None;
    }
    Some(if neg { -(us as i64) } else { us as i64 })
}

// ------------------------------------------------------ execution tiers

/// The tier abstraction the scheduler drives: both the tree-walking
/// [`Interp`] oracle and the bytecode [`Vm`] expose their shared
/// [`Host`] plus a run-one-program entry point, so one scheduler
/// implementation serves both sides of the differential harness.
pub trait TaskRuntime {
    /// The tier's load-time state (globals, instances, meter).
    fn host(&self) -> &Host;
    /// Mutable host access (the scheduler reads `SINGLE` trigger
    /// globals and snapshots the meter around each activation).
    fn host_mut(&mut self) -> &mut Host;
    /// Run one scan of program definition `pid`.
    fn run_program_id(&mut self, pid: usize) -> Result<(), RuntimeError>;
}

impl TaskRuntime for Interp {
    fn host(&self) -> &Host {
        self
    }

    fn host_mut(&mut self) -> &mut Host {
        self
    }

    fn run_program_id(&mut self, pid: usize) -> Result<(), RuntimeError> {
        let name = self.unit.programs[pid].name.clone();
        self.run_program(&name)
    }
}

impl TaskRuntime for Vm {
    fn host(&self) -> &Host {
        self
    }

    fn host_mut(&mut self) -> &mut Host {
        self
    }

    fn run_program_id(&mut self, pid: usize) -> Result<(), RuntimeError> {
        let name = self.unit.programs[pid].name.clone();
        self.run_program(&name)
    }
}

// ------------------------------------------------------------ scheduler

/// Per-task runtime accounting.
#[derive(Debug, Clone)]
pub struct TaskState {
    /// Next simulated release instant (cyclic tasks).
    pub next_release_us: u64,
    /// Accumulated per-task meter across all activations.
    pub meter: Meter,
    /// Completed activations.
    pub activations: u64,
    /// Due releases skipped because higher-priority work had already
    /// consumed the task's whole interval at the release instant.
    pub skipped: u64,
    /// Budget ledger for cyclic tasks (period = the task interval);
    /// `stats.overruns` counts activations whose own execution time
    /// exceeded the interval.
    pub cycle: Option<ScanCycle>,
    /// Last observed value of the `SINGLE` trigger (edge detection).
    last_single: bool,
}

impl TaskState {
    /// Activations whose execution exceeded the task interval.
    pub fn overruns(&self) -> u64 {
        self.cycle.as_ref().map_or(0, |c| c.stats.overruns)
    }
}

/// What one [`TaskScheduler::tick`] did.
#[derive(Debug, Clone, Default)]
pub struct TickReport {
    /// Simulated time of this tick (µs).
    pub now_us: u64,
    /// Task indices that ran, in execution (priority) order.
    pub ran: Vec<usize>,
    /// Task indices that were due but skipped.
    pub skipped: Vec<usize>,
    /// Modeled CPU time consumed by this tick's activations (µs).
    pub busy_us: f64,
}

/// Priority-driven cyclic executive over simulated time.
///
/// Each [`tick`](TaskScheduler::tick) advances the clock to the next
/// cyclic release instant, collects every due task (cyclic releases,
/// `SINGLE` rising edges, freewheeling programs), and runs them
/// highest-priority-first (declaration order breaks ties). Execution
/// cost is the activation's [`Meter`] delta priced through the
/// scheduler's [`HwProfile`]; a due task whose whole interval is
/// already consumed by higher-priority work in the same instant is
/// skipped and counted, so starvation is deterministic and visible.
pub struct TaskScheduler {
    model: TaskModel,
    profile: HwProfile,
    now_us: u64,
    states: Vec<TaskState>,
}

impl TaskScheduler {
    /// Build a scheduler for a compiled task model.
    pub fn new(model: TaskModel, profile: HwProfile) -> TaskScheduler {
        let states = model
            .tasks
            .iter()
            .map(|t| TaskState {
                next_release_us: 0,
                meter: Meter::new(),
                activations: 0,
                skipped: 0,
                cycle: match t.trigger {
                    Trigger::Cyclic { interval_us } => Some(ScanCycle::new(
                        profile.clone(),
                        interval_us as f64,
                    )),
                    _ => None,
                },
                last_single: false,
            })
            .collect();
        TaskScheduler { model, profile, now_us: 0, states }
    }

    /// Build a scheduler from a tier's compiled unit; `None` when the
    /// unit has no CONFIGURATION block.
    pub fn for_runtime(
        rt: &dyn TaskRuntime,
        profile: HwProfile,
    ) -> Option<TaskScheduler> {
        let model = rt.host().task_model()?.clone();
        Some(TaskScheduler::new(model, profile))
    }

    /// The compiled task model this scheduler executes.
    pub fn model(&self) -> &TaskModel {
        &self.model
    }

    /// Current simulated time (µs).
    pub fn now_us(&self) -> u64 {
        self.now_us
    }

    /// Per-task accounting, indexed like [`TaskModel::tasks`].
    pub fn states(&self) -> &[TaskState] {
        &self.states
    }

    /// The accumulated meter of one task.
    pub fn task_meter(&self, task: usize) -> &Meter {
        &self.states[task].meter
    }

    /// Remaining modeled budget (µs) a cyclic task's interval leaves
    /// after `spent_us` of work — the §6.3 slack a yielding ML task
    /// has in one activation. Zero for non-cyclic tasks.
    pub fn interval_budget_us(&self, task: usize, spent_us: f64) -> f64 {
        match self.model.tasks[task].trigger {
            Trigger::Cyclic { interval_us } => {
                (interval_us as f64 - spent_us).max(0.0)
            }
            _ => 0.0,
        }
    }

    /// Advance simulated time to the next release instant and run
    /// every due task highest-priority-first on `rt`. Returns what ran
    /// and what was skipped; errors abort the tick at the failing
    /// program (a real PLC halts the resource on an unhandled fault).
    pub fn tick(
        &mut self,
        rt: &mut dyn TaskRuntime,
    ) -> Result<TickReport, RuntimeError> {
        // Next event: the earliest cyclic release not yet reached. A
        // model with no cyclic tasks stays at the current instant
        // (SINGLE edges and freewheeling programs still run).
        let next = self
            .model
            .tasks
            .iter()
            .zip(&self.states)
            .filter(|(t, _)| matches!(t.trigger, Trigger::Cyclic { .. }))
            .map(|(_, s)| s.next_release_us)
            .min();
        if let Some(t) = next {
            self.now_us = self.now_us.max(t);
        }

        // Collect due tasks; SINGLE edge state updates every tick so a
        // held-high trigger fires exactly once.
        let mut due: Vec<usize> = Vec::new();
        for (i, task) in self.model.tasks.iter().enumerate() {
            match task.trigger {
                Trigger::Cyclic { .. } => {
                    if self.states[i].next_release_us <= self.now_us {
                        due.push(i);
                    }
                }
                Trigger::Single { global } => {
                    let cur = matches!(
                        rt.host().globals.get(global),
                        Some(Value::Bool(true))
                    );
                    if cur && !self.states[i].last_single {
                        due.push(i);
                    }
                    self.states[i].last_single = cur;
                }
                Trigger::Freewheeling => due.push(i),
            }
        }
        // Highest priority (lowest number) first; declaration order
        // breaks ties (stable sort).
        due.sort_by_key(|&i| self.model.tasks[i].priority);

        let mut report = TickReport { now_us: self.now_us, ..TickReport::default() };
        for &i in &due {
            let interval = match self.model.tasks[i].trigger {
                Trigger::Cyclic { interval_us } => {
                    // Catch the release schedule up past `now` whether
                    // the task runs or is skipped — releases are never
                    // replayed.
                    let s = &mut self.states[i];
                    while s.next_release_us <= self.now_us {
                        s.next_release_us += interval_us;
                    }
                    Some(interval_us as f64)
                }
                _ => None,
            };
            // Deterministic starvation: a due cyclic task whose whole
            // interval is already gone to higher-priority work cannot
            // complete before its next release — skip it, visibly.
            if let Some(iv) = interval {
                if report.busy_us >= iv {
                    self.states[i].skipped += 1;
                    report.skipped.push(i);
                    continue;
                }
            }
            let before = rt.host().meter.clone();
            for b in &self.model.tasks[i].programs {
                rt.run_program_id(b.program)?;
            }
            let delta = rt.host().meter.since(&before);
            let exec_us = self.profile.time_us(&delta);
            let s = &mut self.states[i];
            meter_add(&mut s.meter, &delta);
            s.activations += 1;
            if let Some(c) = s.cycle.as_mut() {
                c.record(&delta, &Meter::new());
            }
            report.busy_us += exec_us;
            report.ran.push(i);
        }
        Ok(report)
    }

    /// Run `n` ticks, returning the last report.
    pub fn run_ticks(
        &mut self,
        rt: &mut dyn TaskRuntime,
        n: usize,
    ) -> Result<TickReport, RuntimeError> {
        let mut last = TickReport::default();
        for _ in 0..n {
            last = self.tick(rt)?;
        }
        Ok(last)
    }
}

/// Map an IEC task priority onto the serving tier's bands: 0 (the
/// most urgent control task) → `Control`, 1–3 (detection/monitoring)
/// → `Defense`, everything lower (including freewheeling) → `Batch`.
pub fn serve_priority(priority: u32) -> crate::serve::Priority {
    match priority {
        0 => crate::serve::Priority::Control,
        1..=3 => crate::serve::Priority::Defense,
        _ => crate::serve::Priority::Batch,
    }
}

/// Field-wise meter accumulation (Meter deliberately has no `Add` —
/// the differential harness compares exact deltas, not sums).
fn meter_add(into: &mut Meter, d: &Meter) {
    into.loads += d.loads;
    into.stores += d.stores;
    into.fp_add += d.fp_add;
    into.fp_mul += d.fp_mul;
    into.fp_div += d.fp_div;
    into.fp_trans += d.fp_trans;
    into.int_ops += d.int_ops;
    into.cmp += d.cmp;
    into.fp_cmp += d.fp_cmp;
    into.branches += d.branches;
    into.calls += d.calls;
    into.copy_bytes += d.copy_bytes;
    into.converts += d.converts;
    into.io_calls += d.io_calls;
    into.io_bytes += d.io_bytes;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_literal_forms() {
        assert_eq!(parse_duration_us("100ms"), Some(100_000));
        assert_eq!(parse_duration_us("10MS"), Some(10_000));
        assert_eq!(parse_duration_us("1s500ms"), Some(1_500_000));
        assert_eq!(parse_duration_us("1.5s"), Some(1_500_000));
        assert_eq!(parse_duration_us("2m"), Some(120_000_000));
        assert_eq!(parse_duration_us("1h"), Some(3_600_000_000));
        assert_eq!(parse_duration_us("1d"), Some(86_400_000_000));
        assert_eq!(parse_duration_us("250us"), Some(250));
        assert_eq!(parse_duration_us("1_000ms"), Some(1_000_000));
        assert_eq!(parse_duration_us("-5ms"), Some(-5_000));
        assert_eq!(parse_duration_us("0s"), Some(0));
    }

    #[test]
    fn duration_rejects_malformed() {
        assert_eq!(parse_duration_us(""), None);
        assert_eq!(parse_duration_us("ms"), None);
        assert_eq!(parse_duration_us("10"), None);
        assert_eq!(parse_duration_us("10x"), None);
        assert_eq!(parse_duration_us("10ms5"), None);
        assert_eq!(parse_duration_us("--5ms"), None);
    }

    #[test]
    fn priority_bridge_bands() {
        use crate::serve::Priority;
        assert_eq!(serve_priority(0), Priority::Control);
        assert_eq!(serve_priority(1), Priority::Defense);
        assert_eq!(serve_priority(3), Priority::Defense);
        assert_eq!(serve_priority(4), Priority::Batch);
        assert_eq!(serve_priority(u32::MAX), Priority::Batch);
    }

    #[test]
    fn meter_add_accumulates_every_field() {
        let mut acc = Meter::new();
        let mut d = Meter::new();
        d.loads = 1;
        d.io_bytes = 7;
        d.fp_trans = 3;
        meter_add(&mut acc, &d);
        meter_add(&mut acc, &d);
        assert_eq!(acc.loads, 2);
        assert_eq!(acc.io_bytes, 14);
        assert_eq!(acc.fp_trans, 6);
    }
}
