//! Recursive-descent parser for the ST subset.
//!
//! Grammar follows IEC 61131-3 third edition (the Codesys dialect for
//! `METHOD`/`INTERFACE`/`IMPLEMENTS`, which is what the paper's framework
//! targets).

use super::ast::*;
use super::lexer::{Token, TokenKind as K};

/// Parse failure with position.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub line: u32,
    pub col: u32,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}", self.line, self.col, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a token stream into a [`File`].
pub fn parse(tokens: &[Token]) -> Result<File, ParseError> {
    let mut p = Parser { toks: tokens, i: 0 };
    let mut file = File::default();
    while !p.at_end() {
        match p.peek_kw() {
            Some("TYPE") => file.types.extend(p.type_decl()?),
            Some("INTERFACE") => file.interfaces.push(p.interface_decl()?),
            Some("FUNCTION_BLOCK") => {
                file.function_blocks.push(p.fb_decl()?)
            }
            Some("FUNCTION") => file.functions.push(p.pou_decl("FUNCTION")?),
            Some("PROGRAM") => file.programs.push(p.pou_decl("PROGRAM")?),
            Some("VAR_GLOBAL") => file.globals.push(p.var_block()?),
            Some("CONFIGURATION") => {
                file.configurations.push(p.config_decl()?)
            }
            _ => {
                let t = p.cur();
                return Err(p.err_at(
                    t,
                    format!("expected a top-level declaration, got {:?}", t.kind),
                ));
            }
        }
    }
    Ok(file)
}

struct Parser<'a> {
    toks: &'a [Token],
    i: usize,
}

impl<'a> Parser<'a> {
    // ------------------------------------------------------------ utils
    fn at_end(&self) -> bool {
        self.i >= self.toks.len()
    }

    fn cur(&self) -> &'a Token {
        self.toks.get(self.i).unwrap_or_else(|| self.toks.last().unwrap())
    }

    fn err_at(&self, t: &Token, msg: String) -> ParseError {
        ParseError { line: t.line, col: t.col, message: msg }
    }

    fn err(&self, msg: impl Into<String>) -> ParseError {
        let t = self.cur();
        self.err_at(t, msg.into())
    }

    fn peek_kw(&self) -> Option<&'static str> {
        match &self.toks.get(self.i)?.kind {
            K::Kw(k) => Some(k),
            _ => None,
        }
    }

    fn eat_kw(&mut self, kw: &str) -> bool {
        if self.peek_kw() == Some(kw) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn expect_kw(&mut self, kw: &'static str) -> Result<(), ParseError> {
        if self.eat_kw(kw) {
            Ok(())
        } else {
            Err(self.err(format!("expected {kw}, got {:?}", self.cur().kind)))
        }
    }

    fn eat(&mut self, k: &K) -> bool {
        if !self.at_end() && &self.toks[self.i].kind == k {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn expect(&mut self, k: K) -> Result<(), ParseError> {
        if self.eat(&k) {
            Ok(())
        } else {
            Err(self.err(format!("expected {k:?}, got {:?}", self.cur().kind)))
        }
    }

    fn ident(&mut self) -> Result<(String, u32), ParseError> {
        match &self.cur().kind {
            K::Ident(s) => {
                let line = self.cur().line;
                let s = s.clone();
                self.i += 1;
                Ok((s, line))
            }
            // Type keywords may appear as conversion function names
            // (REAL_TO_INT is an Ident, but allow e.g. `REAL` in
            // SIZEOF(REAL)).
            K::Kw(k)
                if matches!(
                    *k,
                    "BOOL" | "SINT" | "INT" | "DINT" | "LINT" | "USINT"
                        | "UINT" | "UDINT" | "ULINT" | "REAL" | "LREAL"
                        | "BYTE" | "WORD" | "DWORD" | "STRING"
                ) =>
            {
                let line = self.cur().line;
                let s = k.to_string();
                self.i += 1;
                Ok((s, line))
            }
            other => Err(self.err(format!("expected identifier, got {other:?}"))),
        }
    }

    // ----------------------------------------------------- declarations
    /// `TYPE name : STRUCT ... END_STRUCT END_TYPE` (possibly several
    /// struct defs inside one TYPE..END_TYPE).
    fn type_decl(&mut self) -> Result<Vec<TypeDecl>, ParseError> {
        self.expect_kw("TYPE")?;
        let mut out = Vec::new();
        while !self.eat_kw("END_TYPE") {
            let (name, line) = self.ident()?;
            self.expect(K::Colon)?;
            self.expect_kw("STRUCT")?;
            let mut fields = Vec::new();
            while !self.eat_kw("END_STRUCT") {
                fields.extend(self.var_decl_line()?);
            }
            self.eat(&K::Semi);
            out.push(TypeDecl { name, fields, line });
        }
        Ok(out)
    }

    /// `CONFIGURATION name { RESOURCE ... } END_CONFIGURATION` (§2.7).
    fn config_decl(&mut self) -> Result<ConfigDecl, ParseError> {
        self.expect_kw("CONFIGURATION")?;
        let (name, line) = self.ident()?;
        let mut resources = Vec::new();
        while !self.eat_kw("END_CONFIGURATION") {
            if self.at_end() {
                return Err(self.err("unterminated CONFIGURATION"));
            }
            resources.push(self.resource_decl()?);
        }
        self.eat(&K::Semi);
        Ok(ConfigDecl { name, resources, line })
    }

    /// `RESOURCE name ON proc { TASK ... | PROGRAM ... } END_RESOURCE`
    fn resource_decl(&mut self) -> Result<ResourceDecl, ParseError> {
        self.expect_kw("RESOURCE")?;
        let (name, line) = self.ident()?;
        self.expect_kw("ON")?;
        let (on, _) = self.ident()?;
        let mut tasks = Vec::new();
        let mut programs = Vec::new();
        while !self.eat_kw("END_RESOURCE") {
            match self.peek_kw() {
                Some("TASK") => tasks.push(self.task_decl()?),
                Some("PROGRAM") => programs.push(self.prog_bind()?),
                _ => {
                    return Err(self.err(format!(
                        "expected TASK, PROGRAM or END_RESOURCE, got {:?}",
                        self.cur().kind
                    )))
                }
            }
        }
        self.eat(&K::Semi);
        Ok(ResourceDecl { name, on, tasks, programs, line })
    }

    /// `TASK name (INTERVAL := T#10ms, PRIORITY := 1);` /
    /// `TASK name (SINGLE := trigger, PRIORITY := 1);`
    fn task_decl(&mut self) -> Result<TaskDecl, ParseError> {
        self.expect_kw("TASK")?;
        let (name, line) = self.ident()?;
        self.expect(K::LParen)?;
        let mut interval = None;
        let mut single = None;
        let mut priority = None;
        loop {
            let (param, _) = self.ident()?;
            self.expect(K::Assign)?;
            if param.eq_ignore_ascii_case("INTERVAL") {
                // Duration literal: `T#100ms` lexes as Typed("T", ..).
                match &self.cur().kind {
                    K::Typed(ty, lit)
                        if ty.eq_ignore_ascii_case("T")
                            || ty.eq_ignore_ascii_case("TIME") =>
                    {
                        interval = Some(lit.clone());
                        self.i += 1;
                    }
                    other => {
                        return Err(self.err(format!(
                            "INTERVAL expects a T#/TIME# duration literal, \
                             got {other:?}"
                        )))
                    }
                }
            } else if param.eq_ignore_ascii_case("SINGLE") {
                single = Some(self.ident()?.0);
            } else if param.eq_ignore_ascii_case("PRIORITY") {
                priority = Some(self.expr()?);
            } else {
                return Err(self.err(format!(
                    "unknown TASK parameter {param:?} \
                     (expected INTERVAL, SINGLE or PRIORITY)"
                )));
            }
            if !self.eat(&K::Comma) {
                break;
            }
        }
        self.expect(K::RParen)?;
        self.expect(K::Semi)?;
        Ok(TaskDecl { name, interval, single, priority, line })
    }

    /// `PROGRAM inst WITH task : Type;` (`WITH task` optional).
    fn prog_bind(&mut self) -> Result<ProgBind, ParseError> {
        self.expect_kw("PROGRAM")?;
        let (name, line) = self.ident()?;
        let task = if self.eat_kw("WITH") {
            Some(self.ident()?.0)
        } else {
            None
        };
        self.expect(K::Colon)?;
        let (program_type, _) = self.ident()?;
        self.expect(K::Semi)?;
        Ok(ProgBind { name, task, program_type, line })
    }

    fn interface_decl(&mut self) -> Result<InterfaceDecl, ParseError> {
        self.expect_kw("INTERFACE")?;
        let (name, line) = self.ident()?;
        let mut methods = Vec::new();
        while !self.eat_kw("END_INTERFACE") {
            self.expect_kw("METHOD")?;
            let (mname, mline) = self.ident()?;
            let ret = if self.eat(&K::Colon) {
                Some(self.type_ref()?)
            } else {
                None
            };
            let mut inputs = Vec::new();
            while self.peek_kw() == Some("VAR_INPUT") {
                self.i += 1;
                while !self.eat_kw("END_VAR") {
                    inputs.extend(self.var_decl_line()?);
                }
            }
            self.expect_kw("END_METHOD")?;
            methods.push(MethodSig { name: mname, ret, inputs, line: mline });
        }
        Ok(InterfaceDecl { name, methods, line })
    }

    fn fb_decl(&mut self) -> Result<FbDecl, ParseError> {
        self.expect_kw("FUNCTION_BLOCK")?;
        let (name, line) = self.ident()?;
        let mut implements = Vec::new();
        if self.eat_kw("IMPLEMENTS") {
            loop {
                implements.push(self.ident()?.0);
                if !self.eat(&K::Comma) {
                    break;
                }
            }
        }
        let mut blocks = Vec::new();
        while self.at_var_block() {
            blocks.push(self.var_block()?);
        }
        let mut methods = Vec::new();
        while self.peek_kw() == Some("METHOD") {
            methods.push(self.method_decl()?);
        }
        // Optional FB body after methods (classic FB style).
        let mut body = Vec::new();
        while self.peek_kw() != Some("END_FUNCTION_BLOCK") {
            if self.at_end() {
                return Err(self.err("unterminated FUNCTION_BLOCK"));
            }
            body.push(self.stmt()?);
        }
        self.expect_kw("END_FUNCTION_BLOCK")?;
        Ok(FbDecl { name, implements, blocks, methods, body, line })
    }

    fn method_decl(&mut self) -> Result<PouDecl, ParseError> {
        self.expect_kw("METHOD")?;
        let (name, line) = self.ident()?;
        let ret = if self.eat(&K::Colon) {
            Some(self.type_ref()?)
        } else {
            None
        };
        let mut blocks = Vec::new();
        while self.at_var_block() {
            blocks.push(self.var_block()?);
        }
        let mut body = Vec::new();
        while self.peek_kw() != Some("END_METHOD") {
            if self.at_end() {
                return Err(self.err("unterminated METHOD"));
            }
            body.push(self.stmt()?);
        }
        self.expect_kw("END_METHOD")?;
        Ok(PouDecl { name, ret, blocks, body, line })
    }

    fn pou_decl(&mut self, kw: &'static str) -> Result<PouDecl, ParseError> {
        self.expect_kw(kw)?;
        let (name, line) = self.ident()?;
        let ret = if self.eat(&K::Colon) {
            Some(self.type_ref()?)
        } else {
            None
        };
        let mut blocks = Vec::new();
        while self.at_var_block() {
            blocks.push(self.var_block()?);
        }
        let end_kw: &str = match kw {
            "FUNCTION" => "END_FUNCTION",
            _ => "END_PROGRAM",
        };
        let mut body = Vec::new();
        while self.peek_kw() != Some(end_kw) {
            if self.at_end() {
                return Err(self.err(format!("unterminated {kw}")));
            }
            body.push(self.stmt()?);
        }
        self.i += 1; // end keyword
        Ok(PouDecl { name, ret, blocks, body, line })
    }

    fn at_var_block(&self) -> bool {
        matches!(
            self.peek_kw(),
            Some("VAR") | Some("VAR_INPUT") | Some("VAR_OUTPUT")
                | Some("VAR_IN_OUT") | Some("VAR_GLOBAL") | Some("VAR_TEMP")
        )
    }

    fn var_block(&mut self) -> Result<VarBlock, ParseError> {
        let kind = match self.peek_kw() {
            Some("VAR_INPUT") => VarKind::Input,
            Some("VAR_OUTPUT") => VarKind::Output,
            Some("VAR_IN_OUT") => VarKind::InOut,
            Some("VAR_GLOBAL") => VarKind::Global,
            Some("VAR") | Some("VAR_TEMP") => VarKind::Local,
            _ => return Err(self.err("expected VAR section")),
        };
        self.i += 1;
        let constant = self.eat_kw("CONSTANT");
        self.eat_kw("RETAIN");
        let mut decls = Vec::new();
        while !self.eat_kw("END_VAR") {
            decls.extend(self.var_decl_line()?);
        }
        Ok(VarBlock { kind, constant, decls })
    }

    /// `a, b, c : TYPE := init;`
    fn var_decl_line(&mut self) -> Result<Vec<VarDecl>, ParseError> {
        let mut names = Vec::new();
        loop {
            names.push(self.ident()?);
            if !self.eat(&K::Comma) {
                break;
            }
        }
        self.expect(K::Colon)?;
        let ty = self.type_ref()?;
        let init = if self.eat(&K::Assign) {
            Some(self.initializer()?)
        } else {
            None
        };
        self.expect(K::Semi)?;
        Ok(names
            .into_iter()
            .map(|(name, line)| VarDecl {
                name,
                ty: ty.clone(),
                init: init.clone(),
                line,
            })
            .collect())
    }

    fn type_ref(&mut self) -> Result<TypeRef, ParseError> {
        if self.eat_kw("ARRAY") {
            self.expect(K::LBracket)?;
            let mut dims = Vec::new();
            loop {
                let lo = self.expr()?;
                self.expect(K::Range)?;
                let hi = self.expr()?;
                dims.push((lo, hi));
                if !self.eat(&K::Comma) {
                    break;
                }
            }
            self.expect(K::RBracket)?;
            self.expect_kw("OF")?;
            let elem = self.type_ref()?;
            return Ok(TypeRef::Array(dims, Box::new(elem)));
        }
        if self.eat_kw("POINTER") {
            self.expect_kw("TO")?;
            let elem = self.type_ref()?;
            return Ok(TypeRef::Pointer(Box::new(elem)));
        }
        if self.eat_kw("STRING") {
            // Optional length: STRING[80] — accepted and ignored.
            if self.eat(&K::LBracket) {
                self.expr()?;
                self.expect(K::RBracket)?;
            }
            return Ok(TypeRef::StringTy);
        }
        match &self.cur().kind {
            K::Kw(k) => {
                let name = k.to_string();
                self.i += 1;
                Ok(TypeRef::Named(name))
            }
            K::Ident(s) => {
                let name = s.clone();
                self.i += 1;
                Ok(TypeRef::Named(name))
            }
            other => Err(self.err(format!("expected a type, got {other:?}"))),
        }
    }

    fn initializer(&mut self) -> Result<Initializer, ParseError> {
        if self.eat(&K::LBracket) {
            // [e, e, n(e), ...]
            let mut items = Vec::new();
            loop {
                // `n(x)` repetition parses as a call expression (the
                // postfix pass consumes the parens); unwrap it here.
                match self.expr()? {
                    Expr::Call { callee, mut args, .. }
                        if args.len() == 1 && args[0].name.is_none() =>
                    {
                        items.push((Some(*callee), args.remove(0).value));
                    }
                    first => items.push((None, first)),
                }
                if !self.eat(&K::Comma) {
                    break;
                }
            }
            self.expect(K::RBracket)?;
            return Ok(Initializer::Array(items));
        }
        // `(field := expr, ...)` struct initializer vs parenthesized expr:
        // look ahead for `ident :=` after `(`.
        if self.cur().kind == K::LParen {
            if let (Some(K::Ident(_)), Some(K::Assign)) = (
                self.toks.get(self.i + 1).map(|t| &t.kind),
                self.toks.get(self.i + 2).map(|t| &t.kind),
            ) {
                self.i += 1;
                let mut fields = Vec::new();
                loop {
                    let (name, _) = self.ident()?;
                    self.expect(K::Assign)?;
                    let v = self.expr()?;
                    fields.push((name, v));
                    if !self.eat(&K::Comma) {
                        break;
                    }
                }
                self.expect(K::RParen)?;
                return Ok(Initializer::Struct(fields));
            }
        }
        Ok(Initializer::Expr(self.expr()?))
    }

    // ------------------------------------------------------- statements
    fn stmt(&mut self) -> Result<Stmt, ParseError> {
        if self.eat(&K::Semi) {
            return Ok(Stmt::Empty);
        }
        let line = self.cur().line;
        match self.peek_kw() {
            Some("IF") => self.if_stmt(),
            Some("CASE") => self.case_stmt(),
            Some("FOR") => self.for_stmt(),
            Some("WHILE") => self.while_stmt(),
            Some("REPEAT") => self.repeat_stmt(),
            Some("EXIT") => {
                self.i += 1;
                self.expect(K::Semi)?;
                Ok(Stmt::Exit { line })
            }
            Some("CONTINUE") => {
                self.i += 1;
                self.expect(K::Semi)?;
                Ok(Stmt::Continue { line })
            }
            Some("RETURN") => {
                self.i += 1;
                self.expect(K::Semi)?;
                Ok(Stmt::Return { line })
            }
            _ => {
                // assignment or bare call
                let target = self.expr()?;
                if self.eat(&K::Assign) {
                    let value = self.expr()?;
                    self.expect(K::Semi)?;
                    Ok(Stmt::Assign { target, value, line })
                } else {
                    self.expect(K::Semi)?;
                    match target {
                        e @ Expr::Call { .. } => Ok(Stmt::Call { expr: e, line }),
                        _ => Err(ParseError {
                            line,
                            col: 0,
                            message: "expected ':=' or a call statement"
                                .to_string(),
                        }),
                    }
                }
            }
        }
    }

    fn block_until(&mut self, stops: &[&str]) -> Result<Vec<Stmt>, ParseError> {
        let mut out = Vec::new();
        loop {
            match self.peek_kw() {
                Some(k) if stops.contains(&k) => return Ok(out),
                _ if self.at_end() => {
                    return Err(self.err(format!("expected one of {stops:?}")))
                }
                _ => out.push(self.stmt()?),
            }
        }
    }

    fn if_stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.cur().line;
        self.expect_kw("IF")?;
        let mut arms = Vec::new();
        let cond = self.expr()?;
        self.expect_kw("THEN")?;
        let body = self.block_until(&["ELSIF", "ELSE", "END_IF"])?;
        arms.push((cond, body));
        let mut else_body = Vec::new();
        loop {
            if self.eat_kw("ELSIF") {
                let c = self.expr()?;
                self.expect_kw("THEN")?;
                let b = self.block_until(&["ELSIF", "ELSE", "END_IF"])?;
                arms.push((c, b));
            } else if self.eat_kw("ELSE") {
                else_body = self.block_until(&["END_IF"])?;
            } else {
                self.expect_kw("END_IF")?;
                self.eat(&K::Semi);
                return Ok(Stmt::If { arms, else_body, line });
            }
        }
    }

    fn case_stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.cur().line;
        self.expect_kw("CASE")?;
        let scrutinee = self.expr()?;
        self.expect_kw("OF")?;
        let mut arms = Vec::new();
        let mut else_body = Vec::new();
        loop {
            if self.eat_kw("ELSE") {
                else_body = self.block_until(&["END_CASE"])?;
                self.expect_kw("END_CASE")?;
                break;
            }
            if self.eat_kw("END_CASE") {
                break;
            }
            // labels: e [.. e] {, e [.. e]} ':'
            let mut labels = Vec::new();
            loop {
                let a = self.expr()?;
                if self.eat(&K::Range) {
                    let b = self.expr()?;
                    labels.push(CaseLabel::Range(a, b));
                } else {
                    labels.push(CaseLabel::Single(a));
                }
                if !self.eat(&K::Comma) {
                    break;
                }
            }
            self.expect(K::Colon)?;
            let body =
                self.case_arm_body()?;
            arms.push((labels, body));
        }
        self.eat(&K::Semi);
        Ok(Stmt::Case { scrutinee, arms, else_body, line })
    }

    /// A CASE arm body ends at the next label (`expr :`), ELSE, or
    /// END_CASE. We detect labels by scanning for `ident/int [..] :`
    /// lookahead after a statement boundary.
    fn case_arm_body(&mut self) -> Result<Vec<Stmt>, ParseError> {
        let mut out = Vec::new();
        loop {
            match self.peek_kw() {
                Some("ELSE") | Some("END_CASE") => return Ok(out),
                _ => {}
            }
            if self.at_case_label() {
                return Ok(out);
            }
            if self.at_end() {
                return Err(self.err("unterminated CASE"));
            }
            out.push(self.stmt()?);
        }
    }

    fn at_case_label(&self) -> bool {
        // A label is a `,`/`..`-separated list of integer constants or
        // constant names terminated by `:`. Statements can never start
        // with such a sequence followed by a bare `:` (assignment is
        // `:=`, which lexes as one token), so scanning is unambiguous.
        let mut j = self.i;
        let mut saw_item = false;
        while let Some(t) = self.toks.get(j) {
            match &t.kind {
                K::Int(_) | K::Ident(_) | K::Minus | K::Range | K::Comma => {
                    saw_item = true;
                    j += 1;
                }
                K::Colon => return saw_item,
                _ => return false,
            }
        }
        false
    }

    fn for_stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.cur().line;
        self.expect_kw("FOR")?;
        let (var, _) = self.ident()?;
        self.expect(K::Assign)?;
        let from = self.expr()?;
        self.expect_kw("TO")?;
        let to = self.expr()?;
        let by = if self.eat_kw("BY") {
            Some(self.expr()?)
        } else {
            None
        };
        self.expect_kw("DO")?;
        let body = self.block_until(&["END_FOR"])?;
        self.expect_kw("END_FOR")?;
        self.eat(&K::Semi);
        Ok(Stmt::For { var, from, to, by, body, line })
    }

    fn while_stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.cur().line;
        self.expect_kw("WHILE")?;
        let cond = self.expr()?;
        self.expect_kw("DO")?;
        let body = self.block_until(&["END_WHILE"])?;
        self.expect_kw("END_WHILE")?;
        self.eat(&K::Semi);
        Ok(Stmt::While { cond, body, line })
    }

    fn repeat_stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.cur().line;
        self.expect_kw("REPEAT")?;
        let body = self.block_until(&["UNTIL"])?;
        self.expect_kw("UNTIL")?;
        let until = self.expr()?;
        self.expect_kw("END_REPEAT")?;
        self.eat(&K::Semi);
        Ok(Stmt::Repeat { body, until, line })
    }

    // ------------------------------------------------------ expressions
    // Precedence (low→high): OR, XOR, AND, comparison, add, mul, power,
    // unary, postfix.
    fn expr(&mut self) -> Result<Expr, ParseError> {
        self.or_expr()
    }

    fn or_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.xor_expr()?;
        while self.peek_kw() == Some("OR") {
            let line = self.cur().line;
            self.i += 1;
            let rhs = self.xor_expr()?;
            lhs = Expr::Binary(BinOp::Or, Box::new(lhs), Box::new(rhs), line);
        }
        Ok(lhs)
    }

    fn xor_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.and_expr()?;
        while self.peek_kw() == Some("XOR") {
            let line = self.cur().line;
            self.i += 1;
            let rhs = self.and_expr()?;
            lhs = Expr::Binary(BinOp::Xor, Box::new(lhs), Box::new(rhs), line);
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.cmp_expr()?;
        while self.peek_kw() == Some("AND") {
            let line = self.cur().line;
            self.i += 1;
            let rhs = self.cmp_expr()?;
            lhs = Expr::Binary(BinOp::And, Box::new(lhs), Box::new(rhs), line);
        }
        Ok(lhs)
    }

    fn cmp_expr(&mut self) -> Result<Expr, ParseError> {
        let lhs = self.add_expr()?;
        let op = match self.cur().kind {
            K::Eq => BinOp::Eq,
            K::Neq => BinOp::Neq,
            K::Lt => BinOp::Lt,
            K::Gt => BinOp::Gt,
            K::Le => BinOp::Le,
            K::Ge => BinOp::Ge,
            _ => return Ok(lhs),
        };
        let line = self.cur().line;
        self.i += 1;
        let rhs = self.add_expr()?;
        Ok(Expr::Binary(op, Box::new(lhs), Box::new(rhs), line))
    }

    fn add_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.cur().kind {
                K::Plus => BinOp::Add,
                K::Minus => BinOp::Sub,
                _ => return Ok(lhs),
            };
            let line = self.cur().line;
            self.i += 1;
            let rhs = self.mul_expr()?;
            lhs = Expr::Binary(op, Box::new(lhs), Box::new(rhs), line);
        }
    }

    fn mul_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.pow_expr()?;
        loop {
            let op = match &self.cur().kind {
                K::Star => BinOp::Mul,
                K::Slash => BinOp::Div,
                K::Kw("MOD") => BinOp::Mod,
                _ => return Ok(lhs),
            };
            let line = self.cur().line;
            self.i += 1;
            let rhs = self.pow_expr()?;
            lhs = Expr::Binary(op, Box::new(lhs), Box::new(rhs), line);
        }
    }

    fn pow_expr(&mut self) -> Result<Expr, ParseError> {
        let lhs = self.unary_expr()?;
        if self.cur().kind == K::Power {
            let line = self.cur().line;
            self.i += 1;
            let rhs = self.pow_expr()?; // right associative
            return Ok(Expr::Binary(BinOp::Pow, Box::new(lhs), Box::new(rhs), line));
        }
        Ok(lhs)
    }

    fn unary_expr(&mut self) -> Result<Expr, ParseError> {
        let line = self.cur().line;
        if self.eat(&K::Minus) {
            let e = self.unary_expr()?;
            return Ok(Expr::Unary(UnOp::Neg, Box::new(e), line));
        }
        if self.eat(&K::Plus) {
            return self.unary_expr();
        }
        if self.eat_kw("NOT") {
            let e = self.unary_expr()?;
            return Ok(Expr::Unary(UnOp::Not, Box::new(e), line));
        }
        self.postfix_expr()
    }

    fn postfix_expr(&mut self) -> Result<Expr, ParseError> {
        let mut e = self.primary_expr()?;
        loop {
            let line = self.cur().line;
            if self.eat(&K::Dot) {
                let (name, _) = self.ident()?;
                e = Expr::Member(Box::new(e), name, line);
            } else if self.eat(&K::LBracket) {
                let mut idxs = Vec::new();
                loop {
                    idxs.push(self.expr()?);
                    if !self.eat(&K::Comma) {
                        break;
                    }
                }
                self.expect(K::RBracket)?;
                e = Expr::Index(Box::new(e), idxs, line);
            } else if self.eat(&K::Caret) {
                e = Expr::Deref(Box::new(e), line);
            } else if self.cur().kind == K::LParen {
                self.i += 1;
                let args = self.call_args()?;
                e = Expr::Call { callee: Box::new(e), args, line };
            } else {
                return Ok(e);
            }
        }
    }

    fn call_args(&mut self) -> Result<Vec<Arg>, ParseError> {
        let mut args = Vec::new();
        if self.eat(&K::RParen) {
            return Ok(args);
        }
        loop {
            // named? `ident :=` or `ident =>`
            let named = match (
                self.toks.get(self.i).map(|t| &t.kind),
                self.toks.get(self.i + 1).map(|t| &t.kind),
            ) {
                (Some(K::Ident(n)), Some(K::Assign)) => Some((n.clone(), false)),
                (Some(K::Ident(n)), Some(K::Arrow)) => Some((n.clone(), true)),
                _ => None,
            };
            if let Some((name, is_output)) = named {
                self.i += 2;
                let value = self.expr()?;
                args.push(Arg { name: Some(name), is_output, value });
            } else {
                let value = self.expr()?;
                args.push(Arg { name: None, is_output: false, value });
            }
            if self.eat(&K::Comma) {
                continue;
            }
            self.expect(K::RParen)?;
            return Ok(args);
        }
    }

    fn primary_expr(&mut self) -> Result<Expr, ParseError> {
        let t = self.cur().clone();
        match t.kind {
            K::Int(v) => {
                self.i += 1;
                Ok(Expr::IntLit(v))
            }
            K::Real(v) => {
                self.i += 1;
                Ok(Expr::RealLit(v))
            }
            K::Str(s) => {
                self.i += 1;
                Ok(Expr::StrLit(s))
            }
            K::Typed(ty, lit) => {
                self.i += 1;
                Ok(Expr::TypedLit(ty, lit))
            }
            K::Kw("TRUE") => {
                self.i += 1;
                Ok(Expr::BoolLit(true))
            }
            K::Kw("FALSE") => {
                self.i += 1;
                Ok(Expr::BoolLit(false))
            }
            K::Kw("NULL") => {
                self.i += 1;
                Ok(Expr::NullLit)
            }
            K::LParen => {
                // `(ident := ...)` is a struct literal, not parens.
                if let (Some(K::Ident(_)), Some(K::Assign)) = (
                    self.toks.get(self.i + 1).map(|t| &t.kind),
                    self.toks.get(self.i + 2).map(|t| &t.kind),
                ) {
                    let line = t.line;
                    self.i += 1;
                    let mut fields = Vec::new();
                    loop {
                        let (name, _) = self.ident()?;
                        self.expect(K::Assign)?;
                        fields.push((name, self.expr()?));
                        if !self.eat(&K::Comma) {
                            break;
                        }
                    }
                    self.expect(K::RParen)?;
                    return Ok(Expr::StructLit(fields, line));
                }
                self.i += 1;
                let e = self.expr()?;
                self.expect(K::RParen)?;
                Ok(e)
            }
            K::Ident(_) | K::Kw(_) => {
                let (name, line) = self.ident().map_err(|_| {
                    self.err_at(&t, format!("unexpected token {:?}", t.kind))
                })?;
                Ok(Expr::Name(name, line))
            }
            ref other => {
                Err(self.err_at(&t, format!("unexpected token {other:?}")))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn parse_src(src: &str) -> File {
        parse(&lex(src).unwrap()).unwrap()
    }

    #[test]
    fn parses_function() {
        let f = parse_src(
            "FUNCTION add : REAL\n\
             VAR_INPUT a, b : REAL; END_VAR\n\
             add := a + b;\n\
             END_FUNCTION",
        );
        assert_eq!(f.functions.len(), 1);
        let func = &f.functions[0];
        assert_eq!(func.name, "add");
        assert_eq!(func.blocks[0].decls.len(), 2);
        assert_eq!(func.body.len(), 1);
    }

    #[test]
    fn parses_struct_type() {
        let f = parse_src(
            "TYPE dataMem : STRUCT\n\
               address : POINTER TO REAL;\n\
               length : UDINT;\n\
             END_STRUCT END_TYPE",
        );
        assert_eq!(f.types.len(), 1);
        assert_eq!(f.types[0].fields.len(), 2);
        assert!(matches!(f.types[0].fields[0].ty, TypeRef::Pointer(_)));
    }

    #[test]
    fn parses_fb_with_method_and_interface() {
        let f = parse_src(
            "INTERFACE ILayer\n\
               METHOD eval : BOOL END_METHOD\n\
             END_INTERFACE\n\
             FUNCTION_BLOCK FB_X IMPLEMENTS ILayer\n\
             VAR n : INT; END_VAR\n\
             METHOD eval : BOOL\n\
               eval := TRUE;\n\
             END_METHOD\n\
             END_FUNCTION_BLOCK",
        );
        assert_eq!(f.interfaces.len(), 1);
        assert_eq!(f.function_blocks.len(), 1);
        assert_eq!(f.function_blocks[0].implements, vec!["ILayer"]);
        assert_eq!(f.function_blocks[0].methods.len(), 1);
    }

    #[test]
    fn parses_array_decl_with_const_bounds() {
        let f = parse_src(
            "PROGRAM p\n\
             VAR CONSTANT n : INT := 4; END_VAR\n\
             VAR a : ARRAY[0..n*2-1] OF REAL; END_VAR\n\
             END_PROGRAM",
        );
        let decl = &f.programs[0].blocks[1].decls[0];
        assert!(matches!(decl.ty, TypeRef::Array(_, _)));
    }

    #[test]
    fn parses_control_flow() {
        let f = parse_src(
            "PROGRAM p VAR i, s : INT; END_VAR\n\
             FOR i := 0 TO 9 BY 2 DO s := s + i; END_FOR\n\
             WHILE s > 0 DO s := s - 1; END_WHILE\n\
             REPEAT s := s + 1; UNTIL s >= 5 END_REPEAT\n\
             IF s = 5 THEN s := 0; ELSIF s > 5 THEN s := 1; ELSE s := 2; END_IF\n\
             CASE s OF 0: s := 10; 1, 2: s := 20; 3..4: s := 30;\n\
             ELSE s := 40; END_CASE\n\
             END_PROGRAM",
        );
        assert_eq!(f.programs[0].body.len(), 5);
        match &f.programs[0].body[4] {
            Stmt::Case { arms, else_body, .. } => {
                assert_eq!(arms.len(), 3);
                assert_eq!(else_body.len(), 1);
            }
            other => panic!("expected CASE, got {other:?}"),
        }
    }

    #[test]
    fn parses_calls_and_member_chains() {
        let f = parse_src(
            "PROGRAM p VAR m : FB_Model; ok : BOOL; END_VAR\n\
             ok := m.infer();\n\
             m.layers[0] := m.layers[1];\n\
             doit(x := 1, y => ok);\n\
             END_PROGRAM",
        );
        assert_eq!(f.programs[0].body.len(), 3);
    }

    #[test]
    fn parses_pointer_ops() {
        let f = parse_src(
            "PROGRAM p VAR pr : POINTER TO REAL; x : REAL;\n\
             a : ARRAY[0..3] OF REAL; END_VAR\n\
             pr := ADR(a);\n\
             x := pr^ + pr[2];\n\
             END_PROGRAM",
        );
        assert_eq!(f.programs[0].body.len(), 2);
    }

    #[test]
    fn operator_precedence() {
        let f = parse_src(
            "PROGRAM p VAR b : BOOL; x : REAL; END_VAR\n\
             b := x + 1.0 * 2.0 > 3.0 AND NOT b OR b;\n\
             END_PROGRAM",
        );
        // Shape: Or(And(Gt(Add(x, Mul(1,2)), 3), Not(b)), b)
        match &f.programs[0].body[0] {
            Stmt::Assign { value: Expr::Binary(BinOp::Or, _, _, _), .. } => {}
            other => panic!("precedence wrong: {other:?}"),
        }
    }

    #[test]
    fn error_reports_position() {
        let toks = lex("FUNCTION f : REAL\nEND_FUNCTION 42").unwrap();
        let err = parse(&toks).unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn struct_initializer() {
        let f = parse_src(
            "PROGRAM p VAR d : dataMem := (length := 4, num := 1); END_VAR\n\
             END_PROGRAM",
        );
        match &f.programs[0].blocks[0].decls[0].init {
            Some(Initializer::Struct(fields)) => assert_eq!(fields.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn array_initializer_with_repeat() {
        let f = parse_src(
            "PROGRAM p VAR a : ARRAY[0..4] OF INT := [1, 2, 3(9)]; END_VAR\n\
             END_PROGRAM",
        );
        match &f.programs[0].blocks[0].decls[0].init {
            Some(Initializer::Array(items)) => {
                assert_eq!(items.len(), 3);
                assert!(items[2].0.is_some());
            }
            other => panic!("{other:?}"),
        }
    }
}
