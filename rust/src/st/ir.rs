//! Slot-resolved executable IR produced by [`super::lower`].
//!
//! All names are resolved to indices, all types are checked, constant
//! expressions (array bounds, VAR CONSTANT) are folded, and operators
//! are specialized per representation — the interpreter does no name or
//! type resolution at runtime.

use std::sync::Arc;

use super::tasks::TaskModel;
use super::value::Init;

/// IEC integer widths (share `i64` runtime storage).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntTy {
    Sint,
    Usint,
    Int,
    Uint,
    Dint,
    Udint,
    Lint,
    Ulint,
    Byte,
    Word,
    Dword,
}

impl IntTy {
    pub fn bytes(self) -> u32 {
        match self {
            IntTy::Sint | IntTy::Usint | IntTy::Byte => 1,
            IntTy::Int | IntTy::Uint | IntTy::Word => 2,
            IntTy::Dint | IntTy::Udint | IntTy::Dword => 4,
            IntTy::Lint | IntTy::Ulint => 8,
        }
    }

    pub fn signed(self) -> bool {
        matches!(self, IntTy::Sint | IntTy::Int | IntTy::Dint | IntTy::Lint)
    }

    /// Wrap an i64 into this width's value range (IEC overflow
    /// semantics on explicit conversion).
    pub fn wrap(self, v: i64) -> i64 {
        let bits = self.bytes() * 8;
        if bits == 64 {
            return v;
        }
        let m = (1i64 << bits) - 1;
        let w = v & m;
        if self.signed() && (w >> (bits - 1)) & 1 == 1 {
            w - (1i64 << bits)
        } else {
            w
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            IntTy::Sint => "SINT",
            IntTy::Usint => "USINT",
            IntTy::Int => "INT",
            IntTy::Uint => "UINT",
            IntTy::Dint => "DINT",
            IntTy::Udint => "UDINT",
            IntTy::Lint => "LINT",
            IntTy::Ulint => "ULINT",
            IntTy::Byte => "BYTE",
            IntTy::Word => "WORD",
            IntTy::Dword => "DWORD",
        }
    }
}

/// Checked types.
#[derive(Debug, Clone, PartialEq)]
pub enum Ty {
    Bool,
    Int(IntTy),
    Real,
    LReal,
    Str,
    Arr(Box<Ty>, Arc<Vec<(i64, i64)>>),
    Struct(usize),
    Fb(usize),
    Iface(usize),
    Ptr(Box<Ty>),
}

impl Ty {
    /// Total element count for arrays.
    pub fn arr_len(&self) -> Option<usize> {
        match self {
            Ty::Arr(_, dims) => Some(
                dims.iter()
                    .map(|(lo, hi)| (hi - lo + 1).max(0) as usize)
                    .product(),
            ),
            _ => None,
        }
    }

    /// Byte size per SIZEOF (struct sizes computed against `unit`).
    pub fn byte_size(&self, unit: &Unit) -> u64 {
        match self {
            Ty::Bool => 1,
            Ty::Int(it) => it.bytes() as u64,
            Ty::Real => 4,
            Ty::LReal => 8,
            Ty::Str => 81, // default STRING(80) + terminator, Codesys-style
            Ty::Arr(elem, _) => {
                elem.byte_size(unit) * self.arr_len().unwrap_or(0) as u64
            }
            Ty::Struct(id) => unit.structs[*id]
                .fields
                .iter()
                .map(|f| f.ty.byte_size(unit))
                .sum(),
            Ty::Fb(_) | Ty::Iface(_) | Ty::Ptr(_) => 8,
        }
    }
}

/// Array element representation kind (for specialized index ops).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElemKind {
    F32,
    F64,
    Int,
    Ref,
}

/// Pointer target representation kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PtrKind {
    F32,
    F64,
    Int,
}

/// Numeric representation for a binary op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NumKind {
    F32,
    F64,
    Int,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArithOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Pow,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    Eq,
    Neq,
    Lt,
    Gt,
    Le,
    Ge,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoolOp {
    And,
    Or,
    Xor,
}

/// Intrinsic (builtin) operations lowered from calls by name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Builtin {
    Abs,
    Sqrt,
    Exp,
    Ln,
    Log,
    Sin,
    Cos,
    Tan,
    Atan,
    Min,
    Max,
    Limit,
    Trunc,
    Floor,
    /// BINARR(filename, byte_count, pointer): file -> memory.
    BinArr,
    /// ARRBIN(filename, byte_count, pointer): memory -> file.
    ArrBin,
}

/// Typed expressions.
#[derive(Debug, Clone)]
pub enum Ex {
    KBool(bool),
    KInt(i64),
    KReal(f32),
    KLReal(f64),
    KStr(Arc<str>),
    KNull,
    /// Frame slot read.
    Local(u16),
    /// Unit global read.
    Global(u16),
    /// Field of the active FB/program instance.
    SelfField(u16),
    /// Struct field read: `base.field` where base evaluates to Struct.
    Field(Box<Ex>, u16),
    /// FB instance field read: base evaluates to FbRef.
    FbField(Box<Ex>, u16),
    /// `base[flat_index]` with bounds check against `len`.
    Idx(Box<Ex>, Box<Ex>, u32, ElemKind, u32),
    /// Pointer load `p^` / `p[i]` (offset expr optional).
    PtrLoad(Box<Ex>, Option<Box<Ex>>, PtrKind, u32),
    /// ADR(lvalue-of-array / array element).
    Adr(Box<Lv>, PtrKind),
    NegF32(Box<Ex>),
    NegF64(Box<Ex>),
    NegInt(Box<Ex>),
    Not(Box<Ex>),
    Arith(ArithOp, NumKind, Box<Ex>, Box<Ex>, u32),
    Cmp(CmpOp, NumKind, Box<Ex>, Box<Ex>),
    CmpBool(CmpOp, Box<Ex>, Box<Ex>),
    BoolB(BoolOp, Box<Ex>, Box<Ex>),
    /// Bitwise AND/OR/XOR on integers (ANY_BIT).
    IntB(BoolOp, Box<Ex>, Box<Ex>),
    /// Conversions.
    IntToF32(Box<Ex>),
    IntToF64(Box<Ex>),
    F32ToF64(Box<Ex>),
    F64ToF32(Box<Ex>),
    /// REAL->int with IEC round-to-nearest.
    F32ToInt(Box<Ex>, IntTy),
    F64ToInt(Box<Ex>, IntTy),
    /// Integer width conversion (wraps).
    IntNarrow(Box<Ex>, IntTy),
    /// BOOL -> integer 0/1.
    BoolToInt(Box<Ex>),
    /// Struct literal: fresh struct from type defaults + field values.
    StructLit(usize, Vec<(u16, Ex)>),
    /// Function call; bool per arg marks VAR_IN_OUT (by reference — no
    /// copy; otherwise deep-copied + metered).
    CallFn(usize, Vec<Ex>),
    /// Direct FB method call: (fb type, method index, self, args).
    CallMethod(usize, usize, Box<Ex>, Vec<Ex>),
    /// Interface-dispatched call: (iface, iface method id, self, args).
    CallIface(usize, usize, Box<Ex>, Vec<Ex>, u32),
    Intrinsic(Builtin, NumKind, Vec<Ex>, u32),
}

/// Assignable places.
#[derive(Debug, Clone)]
pub enum Lv {
    Local(u16),
    Global(u16),
    SelfField(u16),
    Field(Box<Ex>, u16),
    FbField(Box<Ex>, u16),
    Idx(Box<Ex>, Box<Ex>, u32, ElemKind, u32),
    PtrAt(Box<Ex>, Option<Box<Ex>>, PtrKind, u32),
}

/// Statements.
#[derive(Debug, Clone)]
pub enum St {
    /// `copy` true => deep-copy assignment (array/struct), metered.
    Assign(Lv, Ex, bool),
    If(Vec<(Ex, Vec<St>)>, Vec<St>),
    Case(Ex, Vec<(Arc<Vec<(i64, i64)>>, Vec<St>)>, Vec<St>),
    For {
        var: Lv,
        from: Ex,
        to: Ex,
        by: Option<Ex>,
        body: Vec<St>,
    },
    While(Ex, Vec<St>),
    Repeat(Vec<St>, Ex),
    Exit,
    Continue,
    Return,
    Expr(Ex),
    /// FB invocation: assign inputs, run body, bind outputs.
    FbInvoke {
        fb: Ex,
        fb_id: usize,
        inputs: Vec<(u16, Ex, bool)>,
        outputs: Vec<(u16, Lv)>,
        line: u32,
    },
}

/// Variable (slot / field) definition.
#[derive(Debug, Clone)]
pub struct VarDef {
    pub name: String,
    pub ty: Ty,
    /// Initial-value template (materialized via [`Init::to_value`] on
    /// frame/instance creation). Plain data, so the compiled unit stays
    /// `Send + Sync`.
    pub init: Init,
}

/// A compiled POU body (function, method, FB body, or program body).
#[derive(Debug, Clone)]
pub struct FuncDef {
    pub name: String,
    /// Frame slot layout: slot 0 = return value (if any), then inputs,
    /// then in-outs, then locals.
    pub slots: Vec<VarDef>,
    pub has_ret: bool,
    pub n_inputs: usize,
    pub n_inouts: usize,
    pub body: Vec<St>,
}

#[derive(Debug, Clone)]
pub struct StructDef {
    pub name: String,
    pub fields: Vec<VarDef>,
}

#[derive(Debug, Clone)]
pub struct IfaceDef {
    pub name: String,
    /// Method names in declaration order (ids are indices).
    pub methods: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct FbDef {
    pub name: String,
    pub fields: Vec<VarDef>,
    pub methods: Vec<FuncDef>,
    /// Optional FB body (runs on `inst(...)`), compiled like a method.
    pub body: Option<FuncDef>,
    /// Input/output field indices for FB invocation argument binding.
    pub input_fields: Vec<u16>,
    pub output_fields: Vec<u16>,
    /// vtables\[iface_id\] = Some(method index per iface method id).
    pub vtables: Vec<Option<Vec<usize>>>,
}

/// A compiled PROGRAM: persistent fields + body.
#[derive(Debug, Clone)]
pub struct ProgramDef {
    pub name: String,
    pub fields: Vec<VarDef>,
    pub body: FuncDef,
}

/// A fully lowered compilation unit.
#[derive(Debug, Default, Clone)]
pub struct Unit {
    pub structs: Vec<StructDef>,
    pub ifaces: Vec<IfaceDef>,
    pub fbs: Vec<FbDef>,
    pub funcs: Vec<FuncDef>,
    pub programs: Vec<ProgramDef>,
    pub globals: Vec<VarDef>,
    /// §2.7 task model, when the unit declares a CONFIGURATION block
    /// (executed by [`super::tasks::TaskScheduler`]).
    pub tasks: Option<TaskModel>,
}

impl Unit {
    pub fn find_program(&self, name: &str) -> Option<usize> {
        self.programs
            .iter()
            .position(|p| p.name.eq_ignore_ascii_case(name))
    }

    pub fn find_function(&self, name: &str) -> Option<usize> {
        self.funcs
            .iter()
            .position(|f| f.name.eq_ignore_ascii_case(name))
    }

    pub fn find_global(&self, name: &str) -> Option<usize> {
        self.globals
            .iter()
            .position(|g| g.name.eq_ignore_ascii_case(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_wrap_semantics() {
        assert_eq!(IntTy::Sint.wrap(130), -126);
        assert_eq!(IntTy::Usint.wrap(-1), 255);
        assert_eq!(IntTy::Int.wrap(40_000), 40_000 - 65_536);
        assert_eq!(IntTy::Uint.wrap(-1), 65_535);
        assert_eq!(IntTy::Dint.wrap(1), 1);
        assert_eq!(IntTy::Lint.wrap(i64::MIN), i64::MIN);
    }

    /// SINT boundary behavior: the exact values the §6.1 quantized
    /// weights live at. 127 and -128 are fixed points; one past either
    /// end wraps to the opposite sign.
    #[test]
    fn sint_min_max_edges() {
        assert_eq!(IntTy::Sint.wrap(127), 127);
        assert_eq!(IntTy::Sint.wrap(128), -128);
        assert_eq!(IntTy::Sint.wrap(-128), -128);
        assert_eq!(IntTy::Sint.wrap(-129), 127);
        assert_eq!(IntTy::Sint.wrap(255), -1);
        assert_eq!(IntTy::Sint.wrap(256), 0);
    }

    /// WORD/BYTE/DWORD are unsigned bit-string types: wrap is a pure
    /// mask, never sign-extending.
    #[test]
    fn bitstring_masking() {
        assert_eq!(IntTy::Word.wrap(0x1_FFFF), 0xFFFF);
        assert_eq!(IntTy::Word.wrap(-1), 0xFFFF);
        assert_eq!(IntTy::Word.wrap(0x8000), 0x8000, "no sign extension");
        assert_eq!(IntTy::Byte.wrap(0x100), 0);
        assert_eq!(IntTy::Byte.wrap(-2), 0xFE);
        assert_eq!(IntTy::Dword.wrap(0x1_0000_0000), 0);
        assert_eq!(IntTy::Dword.wrap(-1), 0xFFFF_FFFF);
    }

    /// Signed widths wrap two's-complement at every boundary; 64-bit
    /// widths are identity (no mask exists for them).
    #[test]
    fn signed_wrap_boundaries_and_identity() {
        assert_eq!(IntTy::Int.wrap(32_767), 32_767);
        assert_eq!(IntTy::Int.wrap(32_768), -32_768);
        assert_eq!(IntTy::Int.wrap(-32_769), 32_767);
        assert_eq!(IntTy::Dint.wrap(2_147_483_648), -2_147_483_648);
        assert_eq!(IntTy::Dint.wrap(-2_147_483_649), 2_147_483_647);
        assert_eq!(IntTy::Lint.wrap(i64::MAX), i64::MAX);
        assert_eq!(IntTy::Ulint.wrap(-1), -1, "64-bit storage is identity");
        assert_eq!(IntTy::Udint.wrap(-1), 4_294_967_295);
    }

    #[test]
    fn ty_sizes() {
        let unit = Unit::default();
        assert_eq!(Ty::Real.byte_size(&unit), 4);
        assert_eq!(Ty::Int(IntTy::Sint).byte_size(&unit), 1);
        let arr = Ty::Arr(Box::new(Ty::Real), Arc::new(vec![(0, 9)]));
        assert_eq!(arr.byte_size(&unit), 40);
        assert_eq!(arr.arr_len(), Some(10));
        let arr2 =
            Ty::Arr(Box::new(Ty::Real), Arc::new(vec![(0, 1), (0, 2)]));
        assert_eq!(arr2.arr_len(), Some(6));
    }
}
