//! Register VM executing [`super::bytecode`] — the ST runtime's fast
//! tier.
//!
//! Holds the same load-time state as [`Interp`] (globals, FB-instance
//! arena, program instances, meter, I/O dir) and exposes the same host
//! API, so backends and tests can swap tiers freely. Call frames live
//! in one contiguous `Vec<Value>` register arena: a call pushes the
//! callee's frame onto the arena (return slot, arguments, slot
//! initializers, temporaries) and truncates it on return — replacing
//! the interpreter's `frame_pool` recycling with strictly
//! stack-disciplined storage.
//!
//! Correctness contract: identical outputs *and* identical
//! [`Meter`](super::cost::Meter) counters to the tree-walking oracle on
//! every successful execution, and an error whenever the oracle errors
//! (`tests/st_differential.rs` drives both tiers over the whole
//! end-to-end corpus plus the ICSML MLP models).

use std::ops::{Deref, DerefMut};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;

use super::builtins;
use super::bytecode::{
    self, Code, CodeUnit, CopyMode, FusionConfig, Op, NO_REG,
};
use super::host::Host;
use super::interp::{cmp_ord, copy_into, rerr, Interp, RuntimeError};
use super::ir::*;
use super::value::Value;

/// The bytecode execution tier.
///
/// Load-time state and the by-name host API live in the embedded
/// [`Host`] — the *same* struct [`Interp`] embeds, so name resolution
/// has exactly one implementation across tiers. `Vm` adds the shared
/// compiled [`CodeUnit`] (an `Arc`: one compilation serves every
/// session minted from an ST backend) and the register arena.
pub struct Vm {
    pub host: Host,
    code: Arc<CodeUnit>,
    /// The call-frame arena: every live frame's registers,
    /// stack-disciplined.
    regs: Vec<Value>,
}

impl Deref for Vm {
    type Target = Host;
    fn deref(&self) -> &Host {
        &self.host
    }
}

impl DerefMut for Vm {
    fn deref_mut(&mut self) -> &mut Host {
        &mut self.host
    }
}

impl Vm {
    /// Compile and instantiate a unit (globals, program instances, FB
    /// arena — laid out exactly as [`Interp::new`] lays them out, so
    /// `FbRef` handles are identical across tiers). Uses the default
    /// [`FusionConfig`] (superinstructions on).
    pub fn new(unit: Unit) -> Vm {
        Vm::from_interp(Interp::new(unit))
    }

    /// Like [`Vm::new`] with an explicit [`FusionConfig`] — the plain
    /// (fusion-off) tier exists so every fused run stays differentiable
    /// against the unfused bytecode as well as the interpreter.
    pub fn new_with(unit: Unit, cfg: &FusionConfig) -> Vm {
        Vm::from_interp_with(Interp::new(unit), cfg)
    }

    /// Adopt an interpreter's load-time state wholesale and compile its
    /// unit to bytecode. Any host-side mutation already applied to the
    /// interpreter (globals, instance fields, `io_dir`, meter) carries
    /// over bit-for-bit.
    pub fn from_interp(interp: Interp) -> Vm {
        Vm::from_interp_with(interp, &FusionConfig::default())
    }

    /// [`Vm::from_interp`] with an explicit [`FusionConfig`].
    pub fn from_interp_with(interp: Interp, cfg: &FusionConfig) -> Vm {
        let host = interp.into_host();
        let code = Arc::new(bytecode::compile_unit_with(&host.unit, cfg));
        Vm { host, code, regs: Vec::new() }
    }

    /// Assemble a tier from an already-compiled unit (shared `Arc`)
    /// and a live [`Host`] — the per-session constructor behind the ST
    /// backend: state comes from a restored
    /// [`HostImage`](super::host::HostImage), code is compiled once
    /// and shared.
    pub fn with_host(host: Host, code: Arc<CodeUnit>) -> Vm {
        Vm { host, code, regs: Vec::new() }
    }

    /// The compiled bytecode (shareable across sessions/threads).
    pub fn code(&self) -> &Arc<CodeUnit> {
        &self.code
    }

    /// Set the BINARR/ARRBIN base directory.
    pub fn with_io_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.host.io_dir = dir.into();
        self
    }

    /// Run a PROGRAM body once (one "scan" of that task).
    pub fn run_program(&mut self, name: &str) -> Result<(), RuntimeError> {
        let pid = self
            .unit
            .find_program(name)
            .ok_or_else(|| rerr(0, format!("no program {name}")))?;
        let inst = self.program_instances[pid];
        let unit = Arc::clone(&self.unit);
        let cu = Arc::clone(&self.code);
        let fd = &unit.programs[pid].body;
        let code = &cu.programs[pid];
        let base = self.push_frame_vals(fd, code, Vec::new())?;
        let r = self.exec(code, base, Some(inst));
        self.regs.truncate(base);
        r
    }

    /// Call a FUNCTION by name with host-supplied arguments.
    pub fn call_function(
        &mut self,
        name: &str,
        args: Vec<Value>,
    ) -> Result<Value, RuntimeError> {
        let fid = self
            .unit
            .find_function(name)
            .ok_or_else(|| rerr(0, format!("no function {name}")))?;
        let unit = Arc::clone(&self.unit);
        let cu = Arc::clone(&self.code);
        let fd = &unit.funcs[fid];
        let code = &cu.funcs[fid];
        let base = self.push_frame_vals(fd, code, args)?;
        let r = self.exec(code, base, None);
        let ret = std::mem::replace(&mut self.regs[base], Value::Null);
        self.regs.truncate(base);
        r?;
        Ok(ret)
    }

    /// Call a method on an arena instance by name.
    pub fn call_method(
        &mut self,
        inst: usize,
        method: &str,
        args: Vec<Value>,
    ) -> Result<Value, RuntimeError> {
        let fb_id = self.instances[inst].fb_id;
        let unit = Arc::clone(&self.unit);
        let cu = Arc::clone(&self.code);
        let fb = &unit.fbs[fb_id];
        let midx = fb
            .methods
            .iter()
            .position(|m| m.name.eq_ignore_ascii_case(method))
            .ok_or_else(|| rerr(0, format!("no method {method}")))?;
        let fd = &fb.methods[midx];
        let code = &cu.fb_methods[fb_id][midx];
        let base = self.push_frame_vals(fd, code, args)?;
        let r = self.exec(code, base, Some(inst));
        let ret = std::mem::replace(&mut self.regs[base], Value::Null);
        self.regs.truncate(base);
        r?;
        Ok(ret)
    }

    // ----------------------------------------------------- frame setup
    /// Push a frame whose arguments are host-supplied values. Mirrors
    /// `Interp::run_func`'s metering: calls +1, VAR_INPUT aggregates
    /// deep-copied with bytes metered, VAR_IN_OUT sharing the handle.
    fn push_frame_vals(
        &mut self,
        fd: &FuncDef,
        code: &Code,
        args: Vec<Value>,
    ) -> Result<usize, RuntimeError> {
        self.meter.calls += 1;
        if args.len() != fd.n_inputs + fd.n_inouts {
            return Err(rerr(
                0,
                format!(
                    "{}: expected {} args, got {}",
                    fd.name,
                    fd.n_inputs + fd.n_inouts,
                    args.len()
                ),
            ));
        }
        let base = self.regs.len();
        self.regs.reserve(code.n_regs as usize);
        self.regs.push(fd.slots[0].init.to_value());
        let n_args = args.len();
        for (i, a) in args.into_iter().enumerate() {
            self.push_arg(i < fd.n_inputs, a);
        }
        self.fill_frame(fd, code, n_args);
        Ok(base)
    }

    /// Push a frame whose arguments live in the caller's registers
    /// (moved out; the compiler guarantees argument registers are dead
    /// temps).
    fn push_frame_regs(
        &mut self,
        fd: &FuncDef,
        code: &Code,
        arg_regs: &[u16],
        caller_base: usize,
    ) -> Result<usize, RuntimeError> {
        self.meter.calls += 1;
        if arg_regs.len() != fd.n_inputs + fd.n_inouts {
            return Err(rerr(
                0,
                format!(
                    "{}: expected {} args, got {}",
                    fd.name,
                    fd.n_inputs + fd.n_inouts,
                    arg_regs.len()
                ),
            ));
        }
        let base = self.regs.len();
        self.regs.reserve(code.n_regs as usize);
        self.regs.push(fd.slots[0].init.to_value());
        for (i, &r) in arg_regs.iter().enumerate() {
            let a = std::mem::replace(
                &mut self.regs[caller_base + r as usize],
                Value::Null,
            );
            self.push_arg(i < fd.n_inputs, a);
        }
        self.fill_frame(fd, code, arg_regs.len());
        Ok(base)
    }

    #[inline]
    fn push_arg(&mut self, is_input: bool, a: Value) {
        if is_input && a.is_aggregate() {
            // call-by-value: aggregates copied, bytes metered
            self.meter.copy_bytes += a.byte_size();
            let copy = a.deep_clone();
            self.regs.push(copy);
        } else {
            // scalar input, or VAR_IN_OUT sharing the handle
            self.regs.push(a);
        }
    }

    #[inline]
    fn fill_frame(&mut self, fd: &FuncDef, code: &Code, n_args: usize) {
        for slot in fd.slots.iter().skip(1 + n_args) {
            self.regs.push(slot.init.to_value());
        }
        for _ in fd.slots.len()..code.n_regs as usize {
            self.regs.push(Value::Null);
        }
    }

    // ------------------------------------------------------- execution
    /// Threaded dispatch over the op stream of one frame.
    fn exec(
        &mut self,
        code: &Code,
        base: usize,
        self_idx: Option<usize>,
    ) -> Result<(), RuntimeError> {
        macro_rules! reg {
            ($i:expr) => {
                self.regs[base + $i as usize]
            };
        }
        macro_rules! take {
            ($i:expr) => {
                std::mem::replace(&mut reg!($i), Value::Null)
            };
        }
        let ops = &code.ops;
        let mut pc = 0usize;
        loop {
            match &ops[pc] {
                // -------------------------------------------- constants
                Op::ConstBool { dst, v } => reg!(*dst) = Value::Bool(*v),
                Op::ConstInt { dst, v } => reg!(*dst) = Value::Int(*v),
                Op::ConstF32 { dst, v } => reg!(*dst) = Value::Real(*v),
                Op::ConstF64 { dst, v } => reg!(*dst) = Value::LReal(*v),
                Op::ConstStr { dst, v } => reg!(*dst) = Value::Str(v.clone()),
                Op::ConstNull { dst } => reg!(*dst) = Value::Null,
                Op::Mov { dst, src } => {
                    let v = reg!(*src).clone();
                    reg!(*dst) = v;
                }

                // ------------------------------------------------ reads
                Op::LoadLocal { dst, slot } => {
                    self.meter.loads += 1;
                    let v = reg!(*slot).clone();
                    reg!(*dst) = v;
                }
                Op::LoadGlobal { dst, g } => {
                    self.meter.loads += 1;
                    reg!(*dst) = self.globals[*g as usize].clone();
                }
                Op::LoadSelf { dst, f } => {
                    self.meter.loads += 1;
                    let inst = self_idx
                        .ok_or_else(|| rerr(0, "no self in this context"))?;
                    reg!(*dst) =
                        self.instances[inst].fields[*f as usize].clone();
                }
                Op::LoadField { dst, base: b, f } => {
                    self.meter.loads += 1;
                    let v = match &reg!(*b) {
                        Value::Struct(s) => s.borrow()[*f as usize].clone(),
                        _ => return Err(rerr(0, "field read on non-struct")),
                    };
                    reg!(*dst) = v;
                }
                Op::LoadFbField { dst, base: b, f } => {
                    self.meter.loads += 1;
                    let h = match &reg!(*b) {
                        Value::FbRef(h) => *h,
                        _ => return Err(rerr(0, "FB instance not bound")),
                    };
                    reg!(*dst) = self.instances[h].fields[*f as usize].clone();
                }
                Op::LoadIdx { dst, base: b, idx, len, kind, line } => {
                    let i = reg!(*idx).int();
                    self.meter.loads += 1;
                    if i < 0 || i as u32 >= *len {
                        return Err(rerr(
                            *line,
                            format!(
                                "array index {i} out of bounds (len {len})"
                            ),
                        ));
                    }
                    let i = i as usize;
                    let v = match (kind, &reg!(*b)) {
                        (ElemKind::F32, Value::ArrF32(a)) => {
                            Value::Real(a.borrow()[i])
                        }
                        (ElemKind::F64, Value::ArrF64(a)) => {
                            Value::LReal(a.borrow()[i])
                        }
                        (ElemKind::Int, Value::ArrInt(a)) => {
                            Value::Int(a.borrow()[i])
                        }
                        (ElemKind::Ref, Value::ArrRef(a)) => {
                            a.borrow()[i].clone()
                        }
                        _ => {
                            return Err(rerr(*line, "array read type mismatch"))
                        }
                    };
                    reg!(*dst) = v;
                }
                Op::LoadPtr { dst, p, off, kind, line } => {
                    let extra = if *off == NO_REG {
                        0
                    } else {
                        reg!(*off).int()
                    };
                    self.meter.loads += 1;
                    if extra < 0 {
                        return Err(rerr(*line, "negative pointer offset"));
                    }
                    let v = match (kind, &reg!(*p)) {
                        (PtrKind::F32, Value::PtrF32(a, base_off)) => {
                            let arr = a.borrow();
                            let i = base_off + extra as usize;
                            if i >= arr.len() {
                                return Err(rerr(
                                    *line,
                                    "pointer read out of bounds",
                                ));
                            }
                            Value::Real(arr[i])
                        }
                        (PtrKind::F64, Value::PtrF64(a, base_off)) => {
                            let arr = a.borrow();
                            let i = base_off + extra as usize;
                            if i >= arr.len() {
                                return Err(rerr(
                                    *line,
                                    "pointer read out of bounds",
                                ));
                            }
                            Value::LReal(arr[i])
                        }
                        (PtrKind::Int, Value::PtrInt(a, base_off)) => {
                            let arr = a.borrow();
                            let i = base_off + extra as usize;
                            if i >= arr.len() {
                                return Err(rerr(
                                    *line,
                                    "pointer read out of bounds",
                                ));
                            }
                            Value::Int(arr[i])
                        }
                        (_, Value::Null) => {
                            return Err(rerr(*line, "null pointer read"))
                        }
                        _ => {
                            return Err(rerr(
                                *line,
                                "pointer read type mismatch",
                            ))
                        }
                    };
                    reg!(*dst) = v;
                }

                // -------------------------------------------------- ADR
                Op::AdrLocal { dst, slot, kind } => {
                    self.meter.int_ops += 1;
                    let v = adr_of_array(*kind, reg!(*slot).clone(), 0)?;
                    reg!(*dst) = v;
                }
                Op::AdrGlobal { dst, g, kind } => {
                    self.meter.int_ops += 1;
                    let v =
                        adr_of_array(*kind, self.globals[*g as usize].clone(), 0)?;
                    reg!(*dst) = v;
                }
                Op::AdrSelf { dst, f, kind } => {
                    self.meter.int_ops += 1;
                    let inst = self_idx
                        .ok_or_else(|| rerr(0, "no self in this context"))?;
                    let v = adr_of_array(
                        *kind,
                        self.instances[inst].fields[*f as usize].clone(),
                        0,
                    )?;
                    reg!(*dst) = v;
                }
                Op::AdrField { dst, base: b, f, kind } => {
                    self.meter.int_ops += 1;
                    let fv = match &reg!(*b) {
                        Value::Struct(s) => s.borrow()[*f as usize].clone(),
                        _ => return Err(rerr(0, "ADR through non-struct")),
                    };
                    let v = adr_of_array(*kind, fv, 0)?;
                    reg!(*dst) = v;
                }
                Op::AdrFbField { dst, base: b, f, kind } => {
                    self.meter.int_ops += 1;
                    let h = match &reg!(*b) {
                        Value::FbRef(h) => *h,
                        _ => return Err(rerr(0, "FB instance not bound")),
                    };
                    let fv = self.instances[h].fields[*f as usize].clone();
                    let v = adr_of_array(*kind, fv, 0)?;
                    reg!(*dst) = v;
                }
                Op::AdrIdx { dst, base: b, idx, len, kind, line } => {
                    self.meter.int_ops += 1;
                    let i = reg!(*idx).int();
                    if i < 0 || i as u32 >= *len {
                        return Err(rerr(*line, "ADR index out of bounds"));
                    }
                    let bv = take!(*b);
                    let v = adr_of_array(*kind, bv, i as usize)?;
                    reg!(*dst) = v;
                }
                Op::AdrPtr { dst, p, off, kind, line } => {
                    self.meter.int_ops += 1;
                    let extra = if *off == NO_REG {
                        0
                    } else {
                        reg!(*off).int()
                    };
                    if extra < 0 {
                        return Err(rerr(*line, "negative pointer offset"));
                    }
                    let pv = take!(*p);
                    let v = match (kind, pv) {
                        (PtrKind::F32, Value::PtrF32(a, o)) => {
                            Value::PtrF32(a, o + extra as usize)
                        }
                        (PtrKind::F64, Value::PtrF64(a, o)) => {
                            Value::PtrF64(a, o + extra as usize)
                        }
                        (PtrKind::Int, Value::PtrInt(a, o)) => {
                            Value::PtrInt(a, o + extra as usize)
                        }
                        (_, Value::Null) => {
                            return Err(rerr(*line, "ADR through null pointer"))
                        }
                        _ => {
                            return Err(rerr(*line, "ADR pointer kind mismatch"))
                        }
                    };
                    reg!(*dst) = v;
                }

                // ------------------------------------------------ unary
                Op::NegF32 { dst, src } => {
                    self.meter.fp_add += 1;
                    let v = -reg!(*src).real();
                    reg!(*dst) = Value::Real(v);
                }
                Op::NegF64 { dst, src } => {
                    self.meter.fp_add += 1;
                    let v = -reg!(*src).lreal();
                    reg!(*dst) = Value::LReal(v);
                }
                Op::NegInt { dst, src } => {
                    self.meter.int_ops += 1;
                    let v = -reg!(*src).int();
                    reg!(*dst) = Value::Int(v);
                }
                Op::NotBool { dst, src } => {
                    self.meter.int_ops += 1;
                    let v = !reg!(*src).bool();
                    reg!(*dst) = Value::Bool(v);
                }

                // ------------------------------------------- arithmetic
                Op::ArithF32 { op, dst, a, b, line } => {
                    let x = reg!(*a).real();
                    let y = reg!(*b).real();
                    let v = match op {
                        ArithOp::Add => {
                            self.meter.fp_add += 1;
                            x + y
                        }
                        ArithOp::Sub => {
                            self.meter.fp_add += 1;
                            x - y
                        }
                        ArithOp::Mul => {
                            self.meter.fp_mul += 1;
                            x * y
                        }
                        ArithOp::Div => {
                            self.meter.fp_div += 1;
                            x / y
                        }
                        ArithOp::Pow => {
                            self.meter.fp_trans += 1;
                            x.powf(y)
                        }
                        ArithOp::Mod => {
                            return Err(rerr(*line, "MOD on REAL"))
                        }
                    };
                    reg!(*dst) = Value::Real(v);
                }
                Op::ArithF64 { op, dst, a, b, line } => {
                    let x = reg!(*a).lreal();
                    let y = reg!(*b).lreal();
                    let v = match op {
                        ArithOp::Add => {
                            self.meter.fp_add += 1;
                            x + y
                        }
                        ArithOp::Sub => {
                            self.meter.fp_add += 1;
                            x - y
                        }
                        ArithOp::Mul => {
                            self.meter.fp_mul += 1;
                            x * y
                        }
                        ArithOp::Div => {
                            self.meter.fp_div += 1;
                            x / y
                        }
                        ArithOp::Pow => {
                            self.meter.fp_trans += 1;
                            x.powf(y)
                        }
                        ArithOp::Mod => {
                            return Err(rerr(*line, "MOD on LREAL"))
                        }
                    };
                    reg!(*dst) = Value::LReal(v);
                }
                Op::ArithInt { op, dst, a, b, line } => {
                    self.meter.int_ops += 1;
                    let x = reg!(*a).int();
                    let y = reg!(*b).int();
                    let v = match op {
                        ArithOp::Add => x.wrapping_add(y),
                        ArithOp::Sub => x.wrapping_sub(y),
                        ArithOp::Mul => x.wrapping_mul(y),
                        ArithOp::Div => {
                            if y == 0 {
                                return Err(rerr(
                                    *line,
                                    "integer division by zero",
                                ));
                            }
                            x.wrapping_div(y)
                        }
                        ArithOp::Mod => {
                            if y == 0 {
                                return Err(rerr(*line, "MOD by zero"));
                            }
                            x.wrapping_rem(y)
                        }
                        ArithOp::Pow => {
                            self.meter.fp_trans += 1;
                            (x as f64).powf(y as f64) as i64
                        }
                    };
                    reg!(*dst) = Value::Int(v);
                }
                Op::CmpF32 { op, dst, a, b } => {
                    self.meter.fp_cmp += 1;
                    let r = cmp_ord(
                        *op,
                        reg!(*a).real().partial_cmp(&reg!(*b).real()),
                    );
                    reg!(*dst) = Value::Bool(r);
                }
                Op::CmpF64 { op, dst, a, b } => {
                    self.meter.fp_cmp += 1;
                    let r = cmp_ord(
                        *op,
                        reg!(*a).lreal().partial_cmp(&reg!(*b).lreal()),
                    );
                    reg!(*dst) = Value::Bool(r);
                }
                Op::CmpInt { op, dst, a, b } => {
                    self.meter.cmp += 1;
                    let r =
                        cmp_ord(*op, Some(reg!(*a).int().cmp(&reg!(*b).int())));
                    reg!(*dst) = Value::Bool(r);
                }
                Op::CmpBool { op, dst, a, b } => {
                    self.meter.cmp += 1;
                    let av = reg!(*a).bool();
                    let bv = reg!(*b).bool();
                    let v = match op {
                        CmpOp::Eq => av == bv,
                        CmpOp::Neq => av != bv,
                        _ => return Err(rerr(0, "ordering on BOOL")),
                    };
                    reg!(*dst) = Value::Bool(v);
                }
                Op::BoolB { op, dst, a, b } => {
                    self.meter.int_ops += 1;
                    let av = reg!(*a).bool();
                    let bv = reg!(*b).bool();
                    let v = match op {
                        BoolOp::And => av && bv,
                        BoolOp::Or => av || bv,
                        BoolOp::Xor => av ^ bv,
                    };
                    reg!(*dst) = Value::Bool(v);
                }
                Op::IntB { op, dst, a, b } => {
                    self.meter.int_ops += 1;
                    let av = reg!(*a).int();
                    let bv = reg!(*b).int();
                    let v = match op {
                        BoolOp::And => av & bv,
                        BoolOp::Or => av | bv,
                        BoolOp::Xor => av ^ bv,
                    };
                    reg!(*dst) = Value::Int(v);
                }

                // ------------------------------------------ conversions
                Op::IntToF32 { dst, src } => {
                    self.meter.converts += 1;
                    let v = reg!(*src).int() as f32;
                    reg!(*dst) = Value::Real(v);
                }
                Op::IntToF64 { dst, src } => {
                    self.meter.converts += 1;
                    let v = reg!(*src).int() as f64;
                    reg!(*dst) = Value::LReal(v);
                }
                Op::F32ToF64 { dst, src } => {
                    self.meter.converts += 1;
                    let v = reg!(*src).real() as f64;
                    reg!(*dst) = Value::LReal(v);
                }
                Op::F64ToF32 { dst, src } => {
                    self.meter.converts += 1;
                    let v = reg!(*src).lreal() as f32;
                    reg!(*dst) = Value::Real(v);
                }
                Op::F32ToInt { dst, src, ty } => {
                    self.meter.converts += 1;
                    let v =
                        builtins::real_to_int(reg!(*src).real() as f64, *ty);
                    reg!(*dst) = Value::Int(v);
                }
                Op::F64ToInt { dst, src, ty } => {
                    self.meter.converts += 1;
                    let v = builtins::real_to_int(reg!(*src).lreal(), *ty);
                    reg!(*dst) = Value::Int(v);
                }
                Op::IntNarrow { dst, src, ty } => {
                    self.meter.converts += 1;
                    let v = ty.wrap(reg!(*src).int());
                    reg!(*dst) = Value::Int(v);
                }
                Op::BoolToInt { dst, src } => {
                    self.meter.converts += 1;
                    let v = reg!(*src).bool() as i64;
                    reg!(*dst) = Value::Int(v);
                }

                // ------------------------------------------------ calls
                Op::CallFn { dst, fid, args } => {
                    let unit = Arc::clone(&self.unit);
                    let cu = Arc::clone(&self.code);
                    let fd = &unit.funcs[*fid as usize];
                    let callee = &cu.funcs[*fid as usize];
                    let nbase = self.push_frame_regs(fd, callee, args, base)?;
                    let r = self.exec(callee, nbase, None);
                    let ret =
                        std::mem::replace(&mut self.regs[nbase], Value::Null);
                    self.regs.truncate(nbase);
                    r?;
                    reg!(*dst) = ret;
                }
                Op::CallMethod { dst, fb, midx, self_r, args } => {
                    let inst = match &reg!(*self_r) {
                        Value::FbRef(h) => *h,
                        _ => return Err(rerr(0, "FB instance not bound")),
                    };
                    let unit = Arc::clone(&self.unit);
                    let cu = Arc::clone(&self.code);
                    let fd = &unit.fbs[*fb as usize].methods[*midx as usize];
                    let callee = &cu.fb_methods[*fb as usize][*midx as usize];
                    let nbase = self.push_frame_regs(fd, callee, args, base)?;
                    let r = self.exec(callee, nbase, Some(inst));
                    let ret =
                        std::mem::replace(&mut self.regs[nbase], Value::Null);
                    self.regs.truncate(nbase);
                    r?;
                    reg!(*dst) = ret;
                }
                Op::CallIface { dst, iface, mid, self_r, args, line } => {
                    let inst = match &reg!(*self_r) {
                        Value::FbRef(h) => *h,
                        Value::Null => {
                            return Err(rerr(
                                *line,
                                "interface variable is not bound",
                            ))
                        }
                        _ => return Err(rerr(*line, "bad interface value")),
                    };
                    let fb_id = self.instances[inst].fb_id;
                    let unit = Arc::clone(&self.unit);
                    let cu = Arc::clone(&self.code);
                    let table = unit.fbs[fb_id].vtables[*iface as usize]
                        .as_ref()
                        .ok_or_else(|| {
                            rerr(
                                *line,
                                format!(
                                    "{} does not implement {}",
                                    unit.fbs[fb_id].name,
                                    unit.ifaces[*iface as usize].name
                                ),
                            )
                        })?;
                    let midx = table[*mid as usize];
                    let fd = &unit.fbs[fb_id].methods[midx];
                    let callee = &cu.fb_methods[fb_id][midx];
                    let nbase = self.push_frame_regs(fd, callee, args, base)?;
                    let r = self.exec(callee, nbase, Some(inst));
                    let ret =
                        std::mem::replace(&mut self.regs[nbase], Value::Null);
                    self.regs.truncate(nbase);
                    r?;
                    reg!(*dst) = ret;
                }
                Op::CheckFb { r, line } => {
                    if !matches!(&reg!(*r), Value::FbRef(_)) {
                        return Err(rerr(*line, "FB instance not bound"));
                    }
                }
                Op::InvokeFbBody { fb_r, fb_id, line } => {
                    let inst = match &reg!(*fb_r) {
                        Value::FbRef(h) => *h,
                        _ => return Err(rerr(*line, "FB instance not bound")),
                    };
                    let unit = Arc::clone(&self.unit);
                    let cu = Arc::clone(&self.code);
                    let fd = unit.fbs[*fb_id as usize]
                        .body
                        .as_ref()
                        .ok_or_else(|| rerr(*line, "FB has no body"))?;
                    let callee = cu.fb_bodies[*fb_id as usize]
                        .as_ref()
                        .expect("FB body compiled");
                    let nbase = self.push_frame_regs(fd, callee, &[], base)?;
                    let r = self.exec(callee, nbase, Some(inst));
                    self.regs.truncate(nbase);
                    r?;
                }
                Op::StoreFbInput { fb_r, fidx, src, copy } => {
                    let inst = match &reg!(*fb_r) {
                        Value::FbRef(h) => *h,
                        _ => return Err(rerr(0, "FB instance not bound")),
                    };
                    let v = take!(*src);
                    self.meter.stores += 1;
                    if *copy {
                        self.meter.copy_bytes += v.byte_size();
                        let dst =
                            self.instances[inst].fields[*fidx as usize].clone();
                        copy_into(&v, &dst)?;
                    } else {
                        self.instances[inst].fields[*fidx as usize] = v;
                    }
                }
                Op::LoadFbOutput { dst, fb_r, fidx } => {
                    let inst = match &reg!(*fb_r) {
                        Value::FbRef(h) => *h,
                        _ => return Err(rerr(0, "FB instance not bound")),
                    };
                    // Unmetered, like the interp's direct field clone.
                    reg!(*dst) =
                        self.instances[inst].fields[*fidx as usize].clone();
                }

                // --------------------------------------- struct literal
                Op::StructNew { dst, sid } => {
                    let unit = Arc::clone(&self.unit);
                    let vals: Vec<Value> = unit.structs[*sid as usize]
                        .fields
                        .iter()
                        .map(|f| f.init.to_value())
                        .collect();
                    reg!(*dst) = Value::Struct(Rc::new(
                        std::cell::RefCell::new(vals),
                    ));
                }
                Op::StructSet { s, fidx, src } => {
                    let v = take!(*src);
                    self.meter.stores += 1;
                    match &reg!(*s) {
                        Value::Struct(st) => {
                            st.borrow_mut()[*fidx as usize] = v
                        }
                        _ => {
                            return Err(rerr(0, "struct literal store target"))
                        }
                    }
                }

                // --------------------------------------------- builtins
                Op::Intrinsic { dst, b, kind, args } => {
                    debug_assert!(args.len() <= 4);
                    let mut vals =
                        [Value::Null, Value::Null, Value::Null, Value::Null];
                    for (i, &r) in args.iter().enumerate() {
                        vals[i] = take!(r);
                    }
                    let v = builtins::eval_intrinsic(
                        &mut self.meter,
                        *b,
                        *kind,
                        &vals[..args.len()],
                    );
                    reg!(*dst) = v;
                }
                Op::FileIo { dst, b, args, line } => {
                    let fname = match take!(args[0]) {
                        Value::Str(s) => s,
                        _ => {
                            return Err(rerr(
                                *line,
                                "BINARR/ARRBIN: filename not a STRING",
                            ))
                        }
                    };
                    let bytes = reg!(args[1]).int();
                    let ptr = take!(args[2]);
                    let elem_bytes = if args.len() > 3 {
                        reg!(args[3]).int() as usize
                    } else {
                        4
                    };
                    // Split the borrow through `host` explicitly:
                    // `meter` and `io_dir` both live behind the Deref.
                    let host = &mut self.host;
                    let v = builtins::exec_file_io(
                        &mut host.meter,
                        &host.io_dir,
                        *b,
                        fname.as_ref(),
                        bytes,
                        &ptr,
                        elem_bytes,
                        *line,
                    )?;
                    reg!(*dst) = v;
                }

                // ----------------------------------------------- stores
                Op::StoreLocal { src, slot, copy } => {
                    self.meter.stores += 1;
                    let v = take!(*src);
                    if should_copy(*copy, &v) {
                        self.meter.copy_bytes += v.byte_size();
                        let dst = reg!(*slot).clone();
                        copy_into(&v, &dst)?;
                    } else {
                        reg!(*slot) = v;
                    }
                }
                Op::StoreGlobal { src, g, copy } => {
                    self.meter.stores += 1;
                    let v = take!(*src);
                    if should_copy(*copy, &v) {
                        self.meter.copy_bytes += v.byte_size();
                        let dst = self.globals[*g as usize].clone();
                        copy_into(&v, &dst)?;
                    } else {
                        self.globals[*g as usize] = v;
                    }
                }
                Op::StoreSelf { src, f, copy } => {
                    // assign() bumps once, store_field bumps again.
                    self.meter.stores += 1;
                    let inst = self_idx
                        .ok_or_else(|| rerr(0, "no self in this context"))?;
                    self.meter.stores += 1;
                    let v = take!(*src);
                    if should_copy(*copy, &v) {
                        self.meter.copy_bytes += v.byte_size();
                        let dst =
                            self.instances[inst].fields[*f as usize].clone();
                        copy_into(&v, &dst)?;
                    } else {
                        self.instances[inst].fields[*f as usize] = v;
                    }
                }
                Op::StoreField { src, base: b, f, copy } => {
                    self.meter.stores += 1;
                    let v = take!(*src);
                    let s = match &reg!(*b) {
                        Value::Struct(s) => s.clone(),
                        _ => return Err(rerr(0, "field store on non-struct")),
                    };
                    if should_copy(*copy, &v) {
                        self.meter.copy_bytes += v.byte_size();
                        let dst = s.borrow()[*f as usize].clone();
                        copy_into(&v, &dst)?;
                    } else {
                        s.borrow_mut()[*f as usize] = v;
                    }
                }
                Op::StoreFbField { src, base: b, f, copy } => {
                    // assign() + store_field double bump, like StoreSelf.
                    self.meter.stores += 1;
                    let inst = match &reg!(*b) {
                        Value::FbRef(h) => *h,
                        _ => return Err(rerr(0, "FB instance not bound")),
                    };
                    self.meter.stores += 1;
                    let v = take!(*src);
                    if should_copy(*copy, &v) {
                        self.meter.copy_bytes += v.byte_size();
                        let dst =
                            self.instances[inst].fields[*f as usize].clone();
                        copy_into(&v, &dst)?;
                    } else {
                        self.instances[inst].fields[*f as usize] = v;
                    }
                }
                Op::StoreIdx { src, base: b, idx, len, kind, line } => {
                    self.meter.stores += 1;
                    let i = reg!(*idx).int();
                    if i < 0 || i as u32 >= *len {
                        return Err(rerr(
                            *line,
                            format!(
                                "array index {i} out of bounds (len {len})"
                            ),
                        ));
                    }
                    let i = i as usize;
                    let v = take!(*src);
                    match (kind, &reg!(*b), v) {
                        (ElemKind::F32, Value::ArrF32(a), Value::Real(x)) => {
                            a.borrow_mut()[i] = x;
                        }
                        (ElemKind::F64, Value::ArrF64(a), Value::LReal(x)) => {
                            a.borrow_mut()[i] = x;
                        }
                        (ElemKind::Int, Value::ArrInt(a), Value::Int(x)) => {
                            a.borrow_mut()[i] = x;
                        }
                        (ElemKind::Int, Value::ArrInt(a), Value::Bool(x)) => {
                            a.borrow_mut()[i] = x as i64;
                        }
                        (ElemKind::Ref, Value::ArrRef(a), x) => {
                            a.borrow_mut()[i] = x;
                        }
                        _ => {
                            return Err(rerr(
                                *line,
                                "array element store type mismatch",
                            ))
                        }
                    }
                }
                Op::StorePtr { src, p, off, kind, line } => {
                    self.meter.stores += 1;
                    let extra = if *off == NO_REG {
                        0
                    } else {
                        reg!(*off).int()
                    };
                    if extra < 0 {
                        return Err(rerr(*line, "negative pointer offset"));
                    }
                    let v = take!(*src);
                    match (kind, &reg!(*p), v) {
                        (
                            PtrKind::F32,
                            Value::PtrF32(a, base_off),
                            Value::Real(x),
                        ) => {
                            let i = base_off + extra as usize;
                            let mut arr = a.borrow_mut();
                            if i >= arr.len() {
                                return Err(rerr(
                                    *line,
                                    "pointer store out of bounds",
                                ));
                            }
                            arr[i] = x;
                        }
                        (
                            PtrKind::F64,
                            Value::PtrF64(a, base_off),
                            Value::LReal(x),
                        ) => {
                            let i = base_off + extra as usize;
                            let mut arr = a.borrow_mut();
                            if i >= arr.len() {
                                return Err(rerr(
                                    *line,
                                    "pointer store out of bounds",
                                ));
                            }
                            arr[i] = x;
                        }
                        (
                            PtrKind::Int,
                            Value::PtrInt(a, base_off),
                            Value::Int(x),
                        ) => {
                            let i = base_off + extra as usize;
                            let mut arr = a.borrow_mut();
                            if i >= arr.len() {
                                return Err(rerr(
                                    *line,
                                    "pointer store out of bounds",
                                ));
                            }
                            arr[i] = x;
                        }
                        (_, Value::Null, _) => {
                            return Err(rerr(*line, "null pointer store"))
                        }
                        _ => {
                            return Err(rerr(
                                *line,
                                "pointer store type mismatch",
                            ))
                        }
                    }
                }

                // ----------------------------------------- control flow
                Op::Jump { t } => {
                    pc = *t as usize;
                    continue;
                }
                Op::JumpIfFalse { c, t } => {
                    if !reg!(*c).bool() {
                        pc = *t as usize;
                        continue;
                    }
                }
                Op::BumpBranch => {
                    self.meter.branches += 1;
                }
                Op::CaseJump { src, ranges, t } => {
                    let v = reg!(*src).int();
                    if ranges.iter().any(|(lo, hi)| v >= *lo && v <= *hi) {
                        pc = *t as usize;
                        continue;
                    }
                }
                Op::ForCheck { i, to, step, exit } => {
                    let iv = reg!(*i).int();
                    let tv = reg!(*to).int();
                    let sv = reg!(*step).int();
                    if (sv > 0 && iv > tv) || (sv < 0 && iv < tv) {
                        pc = *exit as usize;
                        continue;
                    }
                    self.meter.branches += 1;
                }
                Op::ForIncr { i, step } => {
                    self.meter.int_ops += 1;
                    let v = reg!(*i).int().wrapping_add(reg!(*step).int());
                    reg!(*i) = Value::Int(v);
                }
                Op::ForStepCheck { step } => {
                    if reg!(*step).int() == 0 {
                        return Err(rerr(0, "FOR step of 0"));
                    }
                }

                // ------------------------- fused superinstructions
                // Meter transparency: each handler replays the exact
                // bumps of its unfused window, in the same
                // bump-vs-read order, so success paths *and* error
                // paths meter identically to the plain stream.
                Op::FusedForHead { i, to, step, var, exit } => {
                    let iv = reg!(*i).int();
                    let tv = reg!(*to).int();
                    let sv = reg!(*step).int();
                    if (sv > 0 && iv > tv) || (sv < 0 && iv < tv) {
                        pc = *exit as usize;
                        continue;
                    }
                    self.meter.branches += 1;
                    self.meter.stores += 1;
                    reg!(*var) = Value::Int(iv);
                }
                Op::FusedForIncrJump { i, step, t } => {
                    self.meter.int_ops += 1;
                    let v = reg!(*i).int().wrapping_add(reg!(*step).int());
                    reg!(*i) = Value::Int(v);
                    pc = *t as usize;
                    continue;
                }
                Op::FusedDotStep { s, pw, px, i, l1, l2 } => {
                    self.meter.loads += 3;
                    let iv = reg!(*i).int();
                    self.meter.loads += 1;
                    let wv = ptr_read_f32(&reg!(*pw), iv, *l1)?;
                    self.meter.loads += 2;
                    let iv2 = reg!(*i).int();
                    self.meter.loads += 1;
                    let xv = ptr_read_f32(&reg!(*px), iv2, *l2)?;
                    self.meter.fp_mul += 1;
                    let prod = wv * xv;
                    let sum = reg!(*s).real() + prod;
                    self.meter.fp_add += 1;
                    self.meter.stores += 1;
                    reg!(*s) = Value::Real(sum);
                }
                Op::FusedMacStep { s, a, p, i, line } => {
                    self.meter.loads += 4;
                    let iv = reg!(*i).int();
                    self.meter.loads += 1;
                    let xv = ptr_read_f32(&reg!(*p), iv, *line)?;
                    self.meter.fp_mul += 1;
                    let prod = reg!(*a).real() * xv;
                    let sum = reg!(*s).real() + prod;
                    self.meter.fp_add += 1;
                    self.meter.stores += 1;
                    reg!(*s) = Value::Real(sum);
                }
                Op::FusedMacLoad { dst, p, a, b, b_self, c, line } => {
                    self.meter.loads += 3;
                    let bv = if *b_self {
                        let inst = self_idx.ok_or_else(|| {
                            rerr(0, "no self in this context")
                        })?;
                        self.instances[inst].fields[*b as usize].int()
                    } else {
                        reg!(*b).int()
                    };
                    self.meter.int_ops += 1;
                    let idx = reg!(*a).int().wrapping_mul(bv);
                    self.meter.loads += 1;
                    self.meter.int_ops += 1;
                    let idx = idx.wrapping_add(reg!(*c).int());
                    self.meter.loads += 1;
                    let wv = ptr_read_f32(&reg!(*p), idx, *line)?;
                    self.meter.stores += 1;
                    reg!(*dst) = Value::Real(wv);
                }
                Op::FusedIfCmpF32Br { slot, k, op, t } => {
                    self.meter.branches += 1;
                    self.meter.loads += 1;
                    self.meter.fp_cmp += 1;
                    let r = cmp_ord(*op, reg!(*slot).real().partial_cmp(k));
                    if !r {
                        pc = *t as usize;
                        continue;
                    }
                }
                Op::ConstPool { dst, idx } => {
                    reg!(*dst) = code.pool[*idx as usize].to_value();
                }
                Op::Ret => return Ok(()),
            }
            pc += 1;
        }
    }
}

#[inline]
fn should_copy(mode: CopyMode, v: &Value) -> bool {
    match mode {
        CopyMode::Copy => true,
        CopyMode::Move => false,
        CopyMode::Auto => v.is_aggregate(),
    }
}

/// One F32 read through a pointer value — the `PtrKind::F32` arm of
/// [`Op::LoadPtr`], shared by the fused handlers. The caller bumps
/// `loads` *before* calling, exactly like the unfused op bumps before
/// its own offset/kind checks.
#[inline]
fn ptr_read_f32(v: &Value, extra: i64, line: u32) -> Result<f32, RuntimeError> {
    if extra < 0 {
        return Err(rerr(line, "negative pointer offset"));
    }
    match v {
        Value::PtrF32(a, base_off) => {
            let arr = a.borrow();
            let i = base_off + extra as usize;
            if i >= arr.len() {
                return Err(rerr(line, "pointer read out of bounds"));
            }
            Ok(arr[i])
        }
        Value::Null => Err(rerr(line, "null pointer read")),
        _ => Err(rerr(line, "pointer read type mismatch")),
    }
}

/// ADR over an array value (offset = element index), mirroring
/// `Interp::adr`'s final match.
#[inline]
fn adr_of_array(
    kind: PtrKind,
    v: Value,
    offset: usize,
) -> Result<Value, RuntimeError> {
    Ok(match (kind, v) {
        (PtrKind::F32, Value::ArrF32(a)) => Value::PtrF32(a, offset),
        (PtrKind::F64, Value::ArrF64(a)) => Value::PtrF64(a, offset),
        (PtrKind::Int, Value::ArrInt(a)) => Value::PtrInt(a, offset),
        (_, other) => {
            return Err(rerr(0, format!("ADR of unsupported value {other:?}")))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::st;

    fn run_both(src: &str, prog: &str, scans: usize) -> (Interp, Vm) {
        let unit = st::compile(src).expect("compile");
        let mut it = Interp::new(unit.clone());
        let mut vm = Vm::new(unit);
        for _ in 0..scans {
            it.run_program(prog).expect("interp run");
            vm.run_program(prog).expect("vm run");
        }
        (it, vm)
    }

    fn assert_state_eq(it: &Interp, vm: &Vm, prog: &str) {
        assert_eq!(it.meter, vm.meter, "meters diverged");
        let pid = it.unit.find_program(prog).unwrap();
        let inst = it.program_instances[pid];
        for f in &it.unit.programs[pid].fields {
            let a = it.instance_field(inst, &f.name).unwrap();
            let b = vm.instance_field(inst, &f.name).unwrap();
            assert!(a.bits_eq(&b), "field {} diverged: {a:?} vs {b:?}", f.name);
        }
    }

    /// In-module smoke only — the full corpus (loops, calls, FBs,
    /// pointers, file I/O, error parity, ICSML models) lives in the
    /// one canonical harness, `tests/st_differential.rs`.
    #[test]
    fn arithmetic_matches_interp() {
        let (it, vm) = run_both(
            "PROGRAM p VAR x : REAL; i : DINT; END_VAR\n\
             x := 2.0 + 3.0 * 4.0 - 1.0 / 2.0;\n\
             i := 17 MOD 5 + 2 * 3;\n\
             END_PROGRAM",
            "p",
            2,
        );
        assert_state_eq(&it, &vm, "p");
    }

    #[test]
    fn fused_dot_kernel_matches_interp_and_plain() {
        let src = "FUNCTION DOT : REAL\n\
             VAR_INPUT pa : POINTER TO REAL; pb : POINTER TO REAL; n : DINT; END_VAR\n\
             VAR s : REAL; i : DINT; END_VAR\n\
             FOR i := 0 TO n - 1 DO\n\
               s := s + pa[i] * pb[i];\n\
             END_FOR\n\
             DOT := s;\n\
             END_FUNCTION\n\
             PROGRAM p VAR a, b : ARRAY[0..7] OF REAL; r : REAL; i : DINT; END_VAR\n\
             FOR i := 0 TO 7 DO\n\
               a[i] := DINT_TO_REAL(i) * 0.5;\n\
               b[i] := DINT_TO_REAL(7 - i);\n\
             END_FOR\n\
             r := DOT(ADR(a), ADR(b), 8);\n\
             END_PROGRAM";
        let unit = st::compile(src).expect("compile");
        let mut it = Interp::new(unit.clone());
        let mut fused =
            Vm::new_with(unit.clone(), &FusionConfig { enabled: true });
        let mut plain = Vm::new_with(unit, &FusionConfig { enabled: false });
        assert!(
            fused.code().fused_ops() > 0,
            "dot kernel should trigger the fusion pass"
        );
        assert_eq!(plain.code().fused_ops(), 0);
        for _ in 0..2 {
            it.run_program("p").expect("interp run");
            fused.run_program("p").expect("fused vm run");
            plain.run_program("p").expect("plain vm run");
        }
        assert_state_eq(&it, &fused, "p");
        assert_state_eq(&it, &plain, "p");
    }

    #[test]
    fn frame_arena_drains_after_calls() {
        let src = "FUNCTION f : DINT VAR_INPUT n : DINT; END_VAR\n\
             f := n * 2;\n\
             END_FUNCTION\n\
             PROGRAM p VAR s : DINT; i : DINT; END_VAR\n\
             FOR i := 0 TO 9 DO s := s + f(i); END_FOR\n\
             END_PROGRAM";
        let unit = st::compile(src).unwrap();
        let mut vm = Vm::new(unit);
        vm.run_program("p").unwrap();
        assert!(vm.regs.is_empty(), "arena leaked {} registers", vm.regs.len());
    }
}
