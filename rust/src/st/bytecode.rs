//! Register bytecode for the ST runtime — the compiled tier.
//!
//! [`super::lower`] already resolves every name, type and slot; this
//! module performs the *second*, mechanical lowering: the [`ir`] tree
//! becomes a flat, register-addressed instruction stream with resolved
//! jump targets. [`super::vm::Vm`] executes it over a contiguous
//! register arena; [`super::interp::Interp`] remains the reference
//! oracle.
//!
//! Register model: each POU body gets a frame of `n_regs` registers.
//! Registers `0..n_slots` *are* the IR frame slots (slot 0 = return
//! value); registers above the slots are expression temporaries
//! assigned by a watermark allocator, so a statement's temps are dead
//! at the next statement boundary.
//!
//! Meter discipline (the hard requirement): every opcode applies
//! exactly the [`super::cost::Meter`] increments the tree-walker
//! applies for the IR node(s) it encodes, so a successful execution
//! meters **identically** on both tiers — the PLC timing model
//! (`plc/profiles.rs`) depends on it, and `tests/st_differential.rs`
//! enforces it. The one tolerated divergence: when execution aborts
//! with a runtime error mid-statement, the two tiers may disagree on
//! counters *after* the already-divergent failure point (the interp
//! pre-bumps some counters before evaluating operands; the VM has
//! already evaluated operands when the op runs). Error programs
//! must still fail on both tiers.
//!
//! Superinstruction tier: when [`FusionConfig`] enables it (the
//! default), [`compile_unit_with`] runs a peephole pass over each
//! compiled body that rewrites the DOT_PRODUCT / FB_Dense hot-loop
//! shapes — load-mul-add accumulate chains, row-major indexed pointer
//! walks, loop head/increment sequences, compare-and-branch guards —
//! into single fused [`Op`] variants, then deduplicates literal
//! constants into a per-body [`Konst`] pool and coalesces away the
//! temp registers the fused windows left dead. Every fused handler in
//! [`super::vm::Vm`] applies exactly the meter increments of its
//! unfused expansion (same counters, same bump-vs-read order), so
//! fusion is invisible to the differential gate; with fusion disabled
//! the emitted stream is byte-identical to the unfused compiler
//! output and the constant pool stays empty.

use std::collections::HashMap;
use std::sync::Arc;

use super::ir::*;
use super::value::Value;

/// Sentinel register meaning "no operand" (e.g. `p^` with no offset).
pub const NO_REG: u16 = u16::MAX;

/// Placeholder for a jump target that is patched before `compile_fn`
/// returns. Deliberately out of range (never a valid pc): a bug that
/// leaves one unpatched indexes past the op stream and fails fast
/// instead of silently jumping to pc 0.
const PENDING: u32 = u32::MAX;

/// How a store treats its value, mirroring `Interp::assign`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CopyMode {
    /// Move the handle/value (scalar assignment).
    Move,
    /// Deep-copy into the destination's storage, metering bytes.
    Copy,
    /// Copy iff the runtime value is an aggregate (FB output binding —
    /// the interp decides by inspecting the value).
    Auto,
}

/// One instruction. `dst`/`a`/`b`/... address registers relative to
/// the executing frame's base; indices into the [`Unit`] (functions,
/// FBs, structs) are resolved at compile time.
#[derive(Debug, Clone)]
pub enum Op {
    // ------------------------------------------------------ constants
    ConstBool { dst: u16, v: bool },
    ConstInt { dst: u16, v: i64 },
    ConstF32 { dst: u16, v: f32 },
    ConstF64 { dst: u16, v: f64 },
    ConstStr { dst: u16, v: Arc<str> },
    ConstNull { dst: u16 },
    /// Unmetered register copy (loop-variable materialization).
    Mov { dst: u16, src: u16 },

    // ----------------------------------------------- reads (loads +1)
    LoadLocal { dst: u16, slot: u16 },
    LoadGlobal { dst: u16, g: u16 },
    LoadSelf { dst: u16, f: u16 },
    LoadField { dst: u16, base: u16, f: u16 },
    LoadFbField { dst: u16, base: u16, f: u16 },
    LoadIdx { dst: u16, base: u16, idx: u16, len: u32, kind: ElemKind, line: u32 },
    LoadPtr { dst: u16, p: u16, off: u16, kind: PtrKind, line: u32 },

    // ---------------------------------------------- ADR (int_ops +1)
    AdrLocal { dst: u16, slot: u16, kind: PtrKind },
    AdrGlobal { dst: u16, g: u16, kind: PtrKind },
    AdrSelf { dst: u16, f: u16, kind: PtrKind },
    AdrField { dst: u16, base: u16, f: u16, kind: PtrKind },
    AdrFbField { dst: u16, base: u16, f: u16, kind: PtrKind },
    AdrIdx { dst: u16, base: u16, idx: u16, len: u32, kind: PtrKind, line: u32 },
    AdrPtr { dst: u16, p: u16, off: u16, kind: PtrKind, line: u32 },

    // ---------------------------------------------------------- unary
    NegF32 { dst: u16, src: u16 },
    NegF64 { dst: u16, src: u16 },
    NegInt { dst: u16, src: u16 },
    NotBool { dst: u16, src: u16 },

    // ------------------------- arithmetic, specialized per repr kind
    ArithF32 { op: ArithOp, dst: u16, a: u16, b: u16, line: u32 },
    ArithF64 { op: ArithOp, dst: u16, a: u16, b: u16, line: u32 },
    ArithInt { op: ArithOp, dst: u16, a: u16, b: u16, line: u32 },
    CmpF32 { op: CmpOp, dst: u16, a: u16, b: u16 },
    CmpF64 { op: CmpOp, dst: u16, a: u16, b: u16 },
    CmpInt { op: CmpOp, dst: u16, a: u16, b: u16 },
    CmpBool { op: CmpOp, dst: u16, a: u16, b: u16 },
    BoolB { op: BoolOp, dst: u16, a: u16, b: u16 },
    IntB { op: BoolOp, dst: u16, a: u16, b: u16 },

    // ------------------------------------- conversions (converts +1)
    IntToF32 { dst: u16, src: u16 },
    IntToF64 { dst: u16, src: u16 },
    F32ToF64 { dst: u16, src: u16 },
    F64ToF32 { dst: u16, src: u16 },
    F32ToInt { dst: u16, src: u16, ty: IntTy },
    F64ToInt { dst: u16, src: u16, ty: IntTy },
    IntNarrow { dst: u16, src: u16, ty: IntTy },
    BoolToInt { dst: u16, src: u16 },

    // ---------------------------------------------------------- calls
    CallFn { dst: u16, fid: u32, args: Box<[u16]> },
    CallMethod { dst: u16, fb: u32, midx: u32, self_r: u16, args: Box<[u16]> },
    CallIface {
        dst: u16,
        iface: u32,
        mid: u32,
        self_r: u16,
        args: Box<[u16]>,
        line: u32,
    },
    /// Validate the FB reference of an `inst(...)` invocation before
    /// its inputs are stored (the interp errors at this point).
    CheckFb { r: u16, line: u32 },
    InvokeFbBody { fb_r: u16, fb_id: u32, line: u32 },
    /// FB-invocation input binding: `store_field` semantics
    /// (stores +1, copy bytes metered when `copy`).
    StoreFbInput { fb_r: u16, fidx: u16, src: u16, copy: bool },
    /// FB-invocation output read: unmetered field clone.
    LoadFbOutput { dst: u16, fb_r: u16, fidx: u16 },

    // ------------------------------------------------- struct literal
    StructNew { dst: u16, sid: u32 },
    StructSet { s: u16, fidx: u16, src: u16 },

    // ------------------------------------------------------ builtins
    Intrinsic { dst: u16, b: Builtin, kind: NumKind, args: Box<[u16]> },
    FileIo { dst: u16, b: Builtin, args: Box<[u16]>, line: u32 },

    // ------------------------------------------------------- stores
    StoreLocal { src: u16, slot: u16, copy: CopyMode },
    StoreGlobal { src: u16, g: u16, copy: CopyMode },
    /// stores +2: `Interp::assign` bumps once, then delegates to
    /// `store_field`, which bumps again. Quirk preserved bit-for-bit.
    StoreSelf { src: u16, f: u16, copy: CopyMode },
    StoreField { src: u16, base: u16, f: u16, copy: CopyMode },
    /// stores +2 — same double-bump as [`Op::StoreSelf`].
    StoreFbField { src: u16, base: u16, f: u16, copy: CopyMode },
    StoreIdx { src: u16, base: u16, idx: u16, len: u32, kind: ElemKind, line: u32 },
    StorePtr { src: u16, p: u16, off: u16, kind: PtrKind, line: u32 },

    // ------------------------------------------------- control flow
    Jump { t: u32 },
    JumpIfFalse { c: u16, t: u32 },
    /// branches +1 (IF / CASE / WHILE / REPEAT decision points).
    BumpBranch,
    /// Jump to `t` when the scrutinee falls in any range (unmetered,
    /// like the interp's label scan).
    CaseJump { src: u16, ranges: Arc<Vec<(i64, i64)>>, t: u32 },
    /// FOR head: jump to `exit` when done (unmetered, matching the
    /// interp's loop-condition test); otherwise branches +1.
    ForCheck { i: u16, to: u16, step: u16, exit: u32 },
    /// int_ops +1; `i += step` (wrapping).
    ForIncr { i: u16, step: u16 },
    /// Errors with "FOR step of 0" like the interp's pre-loop check.
    ForStepCheck { step: u16 },
    Ret,

    // ----------------- fused superinstructions (FusionConfig-gated)
    // Each variant replaces the exact unfused window documented on its
    // matcher in `try_fuse_at`; its VM handler applies the same meter
    // bumps, in the same bump-vs-read order, as the window it stands
    // for. The peephole pass emits these; `compile_fn` never does.
    /// [`Op::ForCheck`] + loop-variable materialization (`Mov` +
    /// `StoreLocal`): exit unmetered, else branches +1 and stores +1.
    FusedForHead { i: u16, to: u16, step: u16, var: u16, exit: u32 },
    /// [`Op::ForIncr`] + back-edge [`Op::Jump`]: int_ops +1.
    FusedForIncrJump { i: u16, step: u16, t: u32 },
    /// `s := s + pw[i] * px[i]` over two `POINTER TO REAL` walks — the
    /// DOT_PRODUCT kernel body. loads +7, fp_mul +1, fp_add +1,
    /// stores +1.
    FusedDotStep { s: u16, pw: u16, px: u16, i: u16, l1: u32, l2: u32 },
    /// `s := s + a * p[i]` (scalar multiplier, one pointer walk — the
    /// pruned FB_Dense accumulate). loads +5, fp_mul +1, fp_add +1,
    /// stores +1.
    FusedMacStep { s: u16, a: u16, p: u16, i: u16, line: u32 },
    /// `dst := p[a * b + c]` (row-major weight fetch; `b` names a
    /// local slot, or a self field when `b_self`). loads +5,
    /// int_ops +2, stores +1.
    FusedMacLoad {
        dst: u16,
        p: u16,
        a: u16,
        b: u16,
        b_self: bool,
        c: u16,
        line: u32,
    },
    /// `IF local <op> k THEN` guard: branches +1, loads +1, fp_cmp +1;
    /// falls through on true, jumps to `t` on false.
    FusedIfCmpF32Br { slot: u16, k: f32, op: CmpOp, t: u32 },
    /// Load constant-pool entry `idx` — unmetered, like the `Const*`
    /// ops it replaces after deduplication.
    ConstPool { dst: u16, idx: u32 },
}

impl Op {
    /// True for the superinstruction variants only the fusion pass
    /// emits (constant-pool loads included).
    pub fn is_fused(&self) -> bool {
        matches!(
            self,
            Op::FusedForHead { .. }
                | Op::FusedForIncrJump { .. }
                | Op::FusedDotStep { .. }
                | Op::FusedMacStep { .. }
                | Op::FusedMacLoad { .. }
                | Op::FusedIfCmpF32Br { .. }
                | Op::ConstPool { .. }
        )
    }
}

/// A deduplicated literal in a [`Code`] body's constant pool.
#[derive(Debug, Clone)]
pub enum Konst {
    /// Any integer literal (all IEC integer types share `i64` repr).
    Int(i64),
    /// REAL literal.
    F32(f32),
    /// LREAL literal.
    F64(f64),
    /// STRING literal.
    Str(Arc<str>),
}

impl Konst {
    /// Materialize the pooled literal as a runtime value.
    pub fn to_value(&self) -> Value {
        match self {
            Konst::Int(v) => Value::Int(*v),
            Konst::F32(v) => Value::Real(*v),
            Konst::F64(v) => Value::LReal(*v),
            Konst::Str(s) => Value::Str(s.clone()),
        }
    }
}

/// A compiled POU body.
#[derive(Debug, Clone)]
pub struct Code {
    pub name: String,
    /// Frame width: IR slots first, expression temps above.
    pub n_regs: u16,
    pub ops: Vec<Op>,
    /// Deduplicated literal pool ([`Op::ConstPool`] operands). Empty
    /// unless the fusion pipeline ran over this body.
    pub pool: Vec<Konst>,
}

/// Compiled bytecode for a whole [`Unit`], indexed in parallel with
/// the unit's own tables.
#[derive(Debug, Default, Clone)]
pub struct CodeUnit {
    pub funcs: Vec<Code>,
    /// `fb_methods[fb_id][method_idx]`.
    pub fb_methods: Vec<Vec<Code>>,
    pub fb_bodies: Vec<Option<Code>>,
    pub programs: Vec<Code>,
}

impl CodeUnit {
    /// Every compiled body in the unit (functions, methods, FB bodies,
    /// programs) — the corpus the invariant tests sweep.
    pub fn all_codes(&self) -> impl Iterator<Item = &Code> {
        self.funcs
            .iter()
            .chain(self.fb_methods.iter().flatten())
            .chain(self.fb_bodies.iter().flatten())
            .chain(self.programs.iter())
    }

    /// Count of fused superinstructions across the unit — zero when
    /// compiled with fusion disabled.
    pub fn fused_ops(&self) -> usize {
        self.all_codes()
            .map(|c| c.ops.iter().filter(|o| o.is_fused()).count())
            .sum()
    }
}

/// Toggle for the superinstruction pipeline (peephole fusion +
/// constant-pool dedup + register coalescing). On by default; with
/// `enabled: false` the compiled stream is byte-identical to the
/// plain `compile_fn` output, which keeps every stage differentiable
/// against the previous tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FusionConfig {
    /// Run the fusion pipeline after the mechanical lowering.
    pub enabled: bool,
}

impl Default for FusionConfig {
    fn default() -> Self {
        FusionConfig { enabled: true }
    }
}

/// Compile every POU body in the unit with the default (fused)
/// configuration.
pub fn compile_unit(unit: &Unit) -> CodeUnit {
    compile_unit_with(unit, &FusionConfig::default())
}

/// Compile every POU body in the unit, then (when enabled) run the
/// fusion pipeline over each body.
pub fn compile_unit_with(unit: &Unit, cfg: &FusionConfig) -> CodeUnit {
    let compile = |fd: &FuncDef| {
        let mut code = compile_fn(fd);
        if cfg.enabled {
            fuse(&mut code, fd.slots.len() as u16);
        }
        code
    };
    CodeUnit {
        funcs: unit.funcs.iter().map(compile).collect(),
        fb_methods: unit
            .fbs
            .iter()
            .map(|fb| fb.methods.iter().map(compile).collect())
            .collect(),
        fb_bodies: unit
            .fbs
            .iter()
            .map(|fb| fb.body.as_ref().map(compile))
            .collect(),
        programs: unit.programs.iter().map(|p| compile(&p.body)).collect(),
    }
}

// Register-file size is a static program-size limit, not a runtime
// condition: slot indices are u16 in the IR itself, and the temp
// watermark only exceeds u16 on a ~65k-deep right-nested expression —
// which the recursive lowerer cannot produce without exhausting its own
// stack first. Treated like the other static IEC limits (panic with
// the POU named), not plumbed through as a typed error.
fn compile_fn(fd: &FuncDef) -> Code {
    let n_slots = fd.slots.len();
    assert!(n_slots < NO_REG as usize, "{}: too many slots", fd.name);
    let mut fc = Fc {
        ops: Vec::new(),
        next: n_slots as u16,
        max: n_slots as u16,
        loops: Vec::new(),
    };
    fc.block(&fd.body);
    fc.ops.push(Op::Ret);
    Code { name: fd.name.clone(), n_regs: fc.max, ops: fc.ops, pool: Vec::new() }
}

// ===================================================== fusion pipeline
//
// Three passes, in order, all per-body and all purely peephole-local:
//
//  1. `fuse` — longest-match-first window rewriting. A window is only
//     fused when no interior pc is a jump target (the window *start*
//     may be one), and every consumed pc is remapped to the fused op's
//     new index so control flow stays exact.
//  2. `pool_constants` — `Const{Int,F32,F64,Str}` ops become
//     [`Op::ConstPool`] loads from a deduplicated per-body pool
//     (floats keyed by bit pattern, so `0.0` and `-0.0` stay
//     distinct).
//  3. `coalesce` — temp registers are renumbered densely in first-use
//     order (slots keep their identity), shrinking `n_regs` by the
//     temps the fused windows no longer touch; smaller frames mean
//     fewer `Null` pushes per call.

/// Run the whole fusion pipeline over one compiled body.
fn fuse(code: &mut Code, n_slots: u16) {
    // Jump-target bitmap over the unfused stream.
    let mut targets = vec![false; code.ops.len() + 1];
    for op in &code.ops {
        match op {
            Op::Jump { t }
            | Op::JumpIfFalse { t, .. }
            | Op::CaseJump { t, .. }
            | Op::ForCheck { exit: t, .. } => targets[*t as usize] = true,
            _ => {}
        }
    }

    // Rebuild left-to-right, recording old-pc -> new-pc for every
    // consumed position (plus the one-past-the-end pc, a valid jump
    // target for exits).
    let old = std::mem::take(&mut code.ops);
    let mut new_ops: Vec<Op> = Vec::with_capacity(old.len());
    let mut map = vec![0u32; old.len() + 1];
    let mut p = 0usize;
    while p < old.len() {
        if let Some((fused, width)) = try_fuse_at(&old, p, n_slots, &targets)
        {
            for q in p..p + width {
                map[q] = new_ops.len() as u32;
            }
            new_ops.push(fused);
            p += width;
        } else {
            map[p] = new_ops.len() as u32;
            new_ops.push(old[p].clone());
            p += 1;
        }
    }
    map[old.len()] = new_ops.len() as u32;

    // Remap every jump field into the rebuilt stream.
    for op in &mut new_ops {
        match op {
            Op::Jump { t }
            | Op::JumpIfFalse { t, .. }
            | Op::CaseJump { t, .. }
            | Op::ForCheck { exit: t, .. }
            | Op::FusedForHead { exit: t, .. }
            | Op::FusedForIncrJump { t, .. }
            | Op::FusedIfCmpF32Br { t, .. } => *t = map[*t as usize],
            _ => {}
        }
    }
    code.ops = new_ops;

    pool_constants(code);
    coalesce(code, n_slots);
}

/// `b as u32 == a as u32 + 1` without u16 overflow.
fn succ(a: u16, b: u16) -> bool {
    b as u32 == a as u32 + 1
}

/// Try every matcher at `p`, longest window first. Returns the fused
/// op and the window width it consumes.
fn try_fuse_at(
    ops: &[Op],
    p: usize,
    n_slots: u16,
    targets: &[bool],
) -> Option<(Op, usize)> {
    let clear = |k: usize| (p + 1..p + k).all(|q| !targets[q]);
    if let Some(w) = ops.get(p..p + 10) {
        if clear(10) {
            if let Some(op) = match_dot_step(w, n_slots) {
                return Some((op, 10));
            }
        }
    }
    if let Some(w) = ops.get(p..p + 8) {
        if clear(8) {
            if let Some(op) = match_mac_step(w, n_slots) {
                return Some((op, 8));
            }
            if let Some(op) = match_mac_load(w, n_slots) {
                return Some((op, 8));
            }
        }
    }
    if let Some(w) = ops.get(p..p + 5) {
        if clear(5) {
            if let Some(op) = match_if_cmp(w, n_slots) {
                return Some((op, 5));
            }
        }
    }
    if let Some(w) = ops.get(p..p + 3) {
        if clear(3) {
            if let Some(op) = match_for_head(w, n_slots) {
                return Some((op, 3));
            }
        }
    }
    if let Some(w) = ops.get(p..p + 2) {
        if clear(2) {
            if let Some(op) = match_incr_jump(w) {
                return Some((op, 2));
            }
        }
    }
    None
}

/// `s := s + pw[i] * px[i]` — the DOT_PRODUCT kernel body, exactly as
/// `compile_fn` lowers it (all four names are local slots, both
/// pointers F32, temps consecutive from the statement watermark).
fn match_dot_step(w: &[Op], n_slots: u16) -> Option<Op> {
    if let [Op::LoadLocal { dst: r0, slot: s }, Op::LoadLocal { dst: r1, slot: pw }, Op::LoadLocal { dst: r2, slot: i }, Op::LoadPtr { dst: d1, p: p1, off: o1, kind: PtrKind::F32, line: l1 }, Op::LoadLocal { dst: r2b, slot: px }, Op::LoadLocal { dst: r3, slot: i2 }, Op::LoadPtr { dst: d2, p: p2, off: o2, kind: PtrKind::F32, line: l2 }, Op::ArithF32 { op: ArithOp::Mul, dst: md, a: ma, b: mb, .. }, Op::ArithF32 { op: ArithOp::Add, dst: ad, a: aa, b: ab, .. }, Op::StoreLocal { src: st, slot: s2, copy: CopyMode::Move }] =
        w
    {
        let shape = *r0 >= n_slots
            && succ(*r0, *r1)
            && succ(*r1, *r2)
            && succ(*r2, *r3)
            && d1 == r1
            && p1 == r1
            && o1 == r2
            && r2b == r2
            && i2 == i
            && d2 == r2
            && p2 == r2
            && o2 == r3
            && md == r1
            && ma == r1
            && mb == r2
            && ad == r0
            && aa == r0
            && ab == r1
            && st == r0
            && s2 == s;
        if shape {
            return Some(Op::FusedDotStep {
                s: *s,
                pw: *pw,
                px: *px,
                i: *i,
                l1: *l1,
                l2: *l2,
            });
        }
    }
    None
}

/// `s := s + a * p[i]` — the pruned FB_Dense accumulate (`s := s +
/// wv * px[i]` with `wv` already loaded).
fn match_mac_step(w: &[Op], n_slots: u16) -> Option<Op> {
    if let [Op::LoadLocal { dst: r0, slot: s }, Op::LoadLocal { dst: r1, slot: a }, Op::LoadLocal { dst: r2, slot: p }, Op::LoadLocal { dst: r3, slot: i }, Op::LoadPtr { dst: d1, p: p1, off: o1, kind: PtrKind::F32, line }, Op::ArithF32 { op: ArithOp::Mul, dst: md, a: ma, b: mb, .. }, Op::ArithF32 { op: ArithOp::Add, dst: ad, a: aa, b: ab, .. }, Op::StoreLocal { src: st, slot: s2, copy: CopyMode::Move }] =
        w
    {
        let shape = *r0 >= n_slots
            && succ(*r0, *r1)
            && succ(*r1, *r2)
            && succ(*r2, *r3)
            && d1 == r2
            && p1 == r2
            && o1 == r3
            && md == r1
            && ma == r1
            && mb == r2
            && ad == r0
            && aa == r0
            && ab == r1
            && st == r0
            && s2 == s;
        if shape {
            return Some(Op::FusedMacStep {
                s: *s,
                a: *a,
                p: *p,
                i: *i,
                line: *line,
            });
        }
    }
    None
}

/// `dst := p[a * b + c]` — the row-major weight fetch
/// (`wv := pw[j * inputs + i]`; `inputs` is a self field inside FB
/// methods, a local in functions).
fn match_mac_load(w: &[Op], n_slots: u16) -> Option<Op> {
    if let [Op::LoadLocal { dst: r0, slot: pp }, Op::LoadLocal { dst: r1, slot: a }, op_b, Op::ArithInt { op: ArithOp::Mul, dst: m1, a: ma, b: mb, .. }, Op::LoadLocal { dst: r2b, slot: c }, Op::ArithInt { op: ArithOp::Add, dst: a1, a: aa, b: ab, .. }, Op::LoadPtr { dst: d, p: p1, off: o1, kind: PtrKind::F32, line }, Op::StoreLocal { src: st, slot: dst_slot, copy: CopyMode::Move }] =
        w
    {
        let (b, b_self, r2) = match op_b {
            Op::LoadLocal { dst, slot } => (*slot, false, *dst),
            Op::LoadSelf { dst, f } => (*f, true, *dst),
            _ => return None,
        };
        let shape = *r0 >= n_slots
            && succ(*r0, *r1)
            && succ(*r1, r2)
            && m1 == r1
            && ma == r1
            && *mb == r2
            && *r2b == r2
            && a1 == r1
            && aa == r1
            && *ab == r2
            && d == r0
            && p1 == r0
            && o1 == r1
            && st == r0;
        if shape {
            return Some(Op::FusedMacLoad {
                dst: *dst_slot,
                p: *pp,
                a: *a,
                b,
                b_self,
                c: *c,
                line: *line,
            });
        }
    }
    None
}

/// `IF local <op> k THEN` over REAL — activation-function guards
/// (`IF x > 0.0 THEN`, `IF wv <> 0.0 THEN`). Only the first IF arm
/// carries the `BumpBranch`, so only that arm fuses.
fn match_if_cmp(w: &[Op], n_slots: u16) -> Option<Op> {
    if let [Op::BumpBranch, Op::LoadLocal { dst: r0, slot }, Op::ConstF32 { dst: r1, v }, Op::CmpF32 { op, dst: cd, a: ca, b: cb }, Op::JumpIfFalse { c, t }] =
        w
    {
        let shape = *r0 >= n_slots
            && succ(*r0, *r1)
            && cd == r0
            && ca == r0
            && cb == r1
            && c == r0;
        if shape {
            return Some(Op::FusedIfCmpF32Br {
                slot: *slot,
                k: *v,
                op: *op,
                t: *t,
            });
        }
    }
    None
}

/// FOR head: check + materialize the loop variable into its local
/// slot. Programs store their loop variable through `StoreSelf`, so
/// only function/method loops (the hot ones) fuse.
fn match_for_head(w: &[Op], n_slots: u16) -> Option<Op> {
    if let [Op::ForCheck { i, to, step, exit }, Op::Mov { dst: rt, src }, Op::StoreLocal { src: st, slot: var, copy: CopyMode::Move }] =
        w
    {
        let shape = *i >= n_slots
            && *to >= n_slots
            && *step >= n_slots
            && *rt >= n_slots
            && src == i
            && st == rt;
        if shape {
            return Some(Op::FusedForHead {
                i: *i,
                to: *to,
                step: *step,
                var: *var,
                exit: *exit,
            });
        }
    }
    None
}

/// FOR tail: increment + back-edge jump. The registers are loop-frame
/// temps, disjoint from anything the jump target reads first.
fn match_incr_jump(w: &[Op]) -> Option<Op> {
    if let [Op::ForIncr { i, step }, Op::Jump { t }] = w {
        return Some(Op::FusedForIncrJump { i: *i, step: *step, t: *t });
    }
    None
}

/// Replace `Const*` literal ops with loads from a deduplicated
/// per-body pool. Floats are keyed by bit pattern so distinct NaNs
/// and signed zeros survive; BOOL/NULL literals stay immediate.
fn pool_constants(code: &mut Code) {
    #[derive(PartialEq, Eq, Hash)]
    enum Key {
        Int(i64),
        F32(u32),
        F64(u64),
        Str(Arc<str>),
    }
    let mut index: HashMap<Key, u32> = HashMap::new();
    let mut pool: Vec<Konst> = Vec::new();
    for op in &mut code.ops {
        let (dst, key, konst) = match op {
            Op::ConstInt { dst, v } => (*dst, Key::Int(*v), Konst::Int(*v)),
            Op::ConstF32 { dst, v } => {
                (*dst, Key::F32(v.to_bits()), Konst::F32(*v))
            }
            Op::ConstF64 { dst, v } => {
                (*dst, Key::F64(v.to_bits()), Konst::F64(*v))
            }
            Op::ConstStr { dst, v } => {
                (*dst, Key::Str(v.clone()), Konst::Str(v.clone()))
            }
            _ => continue,
        };
        let idx = *index.entry(key).or_insert_with(|| {
            pool.push(konst);
            (pool.len() - 1) as u32
        });
        *op = Op::ConstPool { dst, idx };
    }
    code.pool = pool;
}

/// Renumber temp registers densely in first-use order. Slots
/// (`0..n_slots`) keep their identity — they *are* the frame layout —
/// and `n_regs` shrinks by however many temps fusion obsoleted.
fn coalesce(code: &mut Code, n_slots: u16) {
    let mut map = vec![NO_REG; code.n_regs as usize];
    for (s, m) in map.iter_mut().enumerate().take(n_slots as usize) {
        *m = s as u16;
    }
    let mut next = n_slots;
    for op in &mut code.ops {
        for_each_reg(op, &mut |r| {
            if *r != NO_REG && map[*r as usize] == NO_REG {
                map[*r as usize] = next;
                next += 1;
            }
        });
    }
    for op in &mut code.ops {
        for_each_reg(op, &mut |r| {
            if *r != NO_REG {
                *r = map[*r as usize];
            }
        });
    }
    code.n_regs = next;
}

/// Visit every register-typed field of an op (`NO_REG` sentinels
/// included — callers guard). Indices into unit tables (globals,
/// fields, functions, FBs, pool) are *not* registers and are skipped.
fn for_each_reg(op: &mut Op, f: &mut dyn FnMut(&mut u16)) {
    match op {
        Op::ConstBool { dst, .. }
        | Op::ConstInt { dst, .. }
        | Op::ConstF32 { dst, .. }
        | Op::ConstF64 { dst, .. }
        | Op::ConstStr { dst, .. }
        | Op::ConstNull { dst }
        | Op::ConstPool { dst, .. }
        | Op::LoadGlobal { dst, .. }
        | Op::LoadSelf { dst, .. }
        | Op::AdrGlobal { dst, .. }
        | Op::AdrSelf { dst, .. }
        | Op::StructNew { dst, .. } => f(dst),
        Op::Mov { dst, src }
        | Op::NegF32 { dst, src }
        | Op::NegF64 { dst, src }
        | Op::NegInt { dst, src }
        | Op::NotBool { dst, src }
        | Op::IntToF32 { dst, src }
        | Op::IntToF64 { dst, src }
        | Op::F32ToF64 { dst, src }
        | Op::F64ToF32 { dst, src }
        | Op::F32ToInt { dst, src, .. }
        | Op::F64ToInt { dst, src, .. }
        | Op::IntNarrow { dst, src, .. }
        | Op::BoolToInt { dst, src } => {
            f(dst);
            f(src);
        }
        Op::LoadLocal { dst, slot } | Op::AdrLocal { dst, slot, .. } => {
            f(dst);
            f(slot);
        }
        Op::LoadField { dst, base, .. }
        | Op::LoadFbField { dst, base, .. }
        | Op::AdrField { dst, base, .. }
        | Op::AdrFbField { dst, base, .. } => {
            f(dst);
            f(base);
        }
        Op::LoadIdx { dst, base, idx, .. }
        | Op::AdrIdx { dst, base, idx, .. } => {
            f(dst);
            f(base);
            f(idx);
        }
        Op::LoadPtr { dst, p, off, .. } | Op::AdrPtr { dst, p, off, .. } => {
            f(dst);
            f(p);
            f(off);
        }
        Op::ArithF32 { dst, a, b, .. }
        | Op::ArithF64 { dst, a, b, .. }
        | Op::ArithInt { dst, a, b, .. }
        | Op::CmpF32 { dst, a, b, .. }
        | Op::CmpF64 { dst, a, b, .. }
        | Op::CmpInt { dst, a, b, .. }
        | Op::CmpBool { dst, a, b, .. }
        | Op::BoolB { dst, a, b, .. }
        | Op::IntB { dst, a, b, .. } => {
            f(dst);
            f(a);
            f(b);
        }
        Op::CallFn { dst, args, .. } => {
            f(dst);
            for r in args.iter_mut() {
                f(r);
            }
        }
        Op::CallMethod { dst, self_r, args, .. }
        | Op::CallIface { dst, self_r, args, .. } => {
            f(dst);
            f(self_r);
            for r in args.iter_mut() {
                f(r);
            }
        }
        Op::CheckFb { r, .. } => f(r),
        Op::InvokeFbBody { fb_r, .. } => f(fb_r),
        Op::StoreFbInput { fb_r, src, .. } => {
            f(fb_r);
            f(src);
        }
        Op::LoadFbOutput { dst, fb_r, .. } => {
            f(dst);
            f(fb_r);
        }
        Op::StructSet { s, src, .. } => {
            f(s);
            f(src);
        }
        Op::Intrinsic { dst, args, .. } | Op::FileIo { dst, args, .. } => {
            f(dst);
            for r in args.iter_mut() {
                f(r);
            }
        }
        Op::StoreLocal { src, slot, .. } => {
            f(src);
            f(slot);
        }
        Op::StoreGlobal { src, .. } | Op::StoreSelf { src, .. } => f(src),
        Op::StoreField { src, base, .. } | Op::StoreFbField { src, base, .. } => {
            f(src);
            f(base);
        }
        Op::StoreIdx { src, base, idx, .. } => {
            f(src);
            f(base);
            f(idx);
        }
        Op::StorePtr { src, p, off, .. } => {
            f(src);
            f(p);
            f(off);
        }
        Op::JumpIfFalse { c, .. } => f(c),
        Op::CaseJump { src, .. } => f(src),
        Op::ForCheck { i, to, step, .. } => {
            f(i);
            f(to);
            f(step);
        }
        Op::ForIncr { i, step } => {
            f(i);
            f(step);
        }
        Op::ForStepCheck { step } => f(step),
        Op::FusedForHead { i, to, step, var, .. } => {
            f(i);
            f(to);
            f(step);
            f(var);
        }
        Op::FusedForIncrJump { i, step, .. } => {
            f(i);
            f(step);
        }
        Op::FusedDotStep { s, pw, px, i, .. } => {
            f(s);
            f(pw);
            f(px);
            f(i);
        }
        Op::FusedMacStep { s, a, p, i, .. } => {
            f(s);
            f(a);
            f(p);
            f(i);
        }
        Op::FusedMacLoad { dst, p, a, b, b_self, c, .. } => {
            f(dst);
            f(p);
            f(a);
            if !*b_self {
                f(b);
            }
            f(c);
        }
        Op::FusedIfCmpF32Br { slot, .. } => f(slot),
        Op::Jump { .. } | Op::BumpBranch | Op::Ret => {}
    }
}

#[derive(Default)]
struct LoopFrame {
    exit_patches: Vec<usize>,
    cont_patches: Vec<usize>,
}

/// Per-body compiler state.
struct Fc {
    ops: Vec<Op>,
    /// Watermark temp allocator: next free register.
    next: u16,
    max: u16,
    loops: Vec<LoopFrame>,
}

impl Fc {
    fn alloc(&mut self) -> u16 {
        let r = self.next;
        self.next = self
            .next
            .checked_add(1)
            .filter(|&n| n < NO_REG)
            .expect("register file overflow");
        if self.next > self.max {
            self.max = self.next;
        }
        r
    }

    fn emit(&mut self, op: Op) -> usize {
        self.ops.push(op);
        self.ops.len() - 1
    }

    fn here(&self) -> u32 {
        self.ops.len() as u32
    }

    fn patch(&mut self, idx: usize, target: u32) {
        match &mut self.ops[idx] {
            Op::Jump { t }
            | Op::JumpIfFalse { t, .. }
            | Op::CaseJump { t, .. }
            | Op::ForCheck { exit: t, .. } => *t = target,
            other => unreachable!("patching non-jump {other:?}"),
        }
    }

    fn block(&mut self, body: &[St]) {
        for st in body {
            let mark = self.next;
            self.stmt(st);
            self.next = mark;
        }
    }

    // ------------------------------------------------------ statements
    fn stmt(&mut self, st: &St) {
        match st {
            St::Assign(lv, e, copy) => {
                let r = self.ex(e);
                let mode = if *copy { CopyMode::Copy } else { CopyMode::Move };
                self.store_lv(lv, r, mode);
            }
            St::If(arms, else_body) => {
                self.emit(Op::BumpBranch);
                let mut end_patches = Vec::new();
                for (cond, body) in arms {
                    let mark = self.next;
                    let rc = self.ex(cond);
                    self.next = mark;
                    let jf = self.emit(Op::JumpIfFalse { c: rc, t: PENDING });
                    self.block(body);
                    end_patches.push(self.emit(Op::Jump { t: PENDING }));
                    let after = self.here();
                    self.patch(jf, after);
                }
                self.block(else_body);
                let end = self.here();
                for p in end_patches {
                    self.patch(p, end);
                }
            }
            St::Case(scrut, arms, else_body) => {
                self.emit(Op::BumpBranch);
                let mark = self.next;
                let rs = self.ex(scrut);
                let mut arm_jumps = Vec::new();
                for (ranges, _) in arms {
                    arm_jumps.push(self.emit(Op::CaseJump {
                        src: rs,
                        ranges: ranges.clone(),
                        t: PENDING,
                    }));
                }
                let else_jump = self.emit(Op::Jump { t: PENDING });
                self.next = mark;
                let mut end_patches = Vec::new();
                for (j, (_, body)) in arms.iter().enumerate() {
                    let here = self.here();
                    self.patch(arm_jumps[j], here);
                    self.block(body);
                    end_patches.push(self.emit(Op::Jump { t: PENDING }));
                }
                let else_at = self.here();
                self.patch(else_jump, else_at);
                self.block(else_body);
                let end = self.here();
                for p in end_patches {
                    self.patch(p, end);
                }
            }
            St::For { var, from, to, by, body } => {
                // Loop registers live for the whole statement.
                let ri = self.ex(from);
                let rto = self.ex(to);
                let rstep = match by {
                    Some(b) => self.ex(b),
                    None => {
                        let d = self.alloc();
                        self.emit(Op::ConstInt { dst: d, v: 1 });
                        d
                    }
                };
                let rtmp = self.alloc();
                self.emit(Op::ForStepCheck { step: rstep });
                let head = self.here();
                let fc =
                    self.emit(Op::ForCheck { i: ri, to: rto, step: rstep, exit: PENDING });
                self.emit(Op::Mov { dst: rtmp, src: ri });
                let mark = self.next;
                self.store_lv(var, rtmp, CopyMode::Move);
                self.next = mark;
                self.loops.push(LoopFrame::default());
                self.block(body);
                let lf = self.loops.pop().unwrap();
                let cont = self.here();
                for p in lf.cont_patches {
                    self.patch(p, cont);
                }
                self.emit(Op::ForIncr { i: ri, step: rstep });
                self.emit(Op::Jump { t: head });
                let exit = self.here();
                self.patch(fc, exit);
                for p in lf.exit_patches {
                    self.patch(p, exit);
                }
            }
            St::While(cond, body) => {
                let head = self.here();
                self.emit(Op::BumpBranch);
                let mark = self.next;
                let rc = self.ex(cond);
                self.next = mark;
                let jf = self.emit(Op::JumpIfFalse { c: rc, t: PENDING });
                self.loops.push(LoopFrame::default());
                self.block(body);
                let lf = self.loops.pop().unwrap();
                for p in lf.cont_patches {
                    self.patch(p, head);
                }
                self.emit(Op::Jump { t: head });
                let exit = self.here();
                self.patch(jf, exit);
                for p in lf.exit_patches {
                    self.patch(p, exit);
                }
            }
            St::Repeat(body, until) => {
                let top = self.here();
                self.loops.push(LoopFrame::default());
                self.block(body);
                let lf = self.loops.pop().unwrap();
                let cont = self.here();
                for p in lf.cont_patches {
                    self.patch(p, cont);
                }
                self.emit(Op::BumpBranch);
                let mark = self.next;
                let ru = self.ex(until);
                self.next = mark;
                self.emit(Op::JumpIfFalse { c: ru, t: top });
                let exit = self.here();
                for p in lf.exit_patches {
                    self.patch(p, exit);
                }
            }
            // EXIT/CONTINUE outside a loop end the POU (the interp's
            // Flow propagates to run_func); lower rejects them anyway.
            St::Exit => {
                if self.loops.is_empty() {
                    self.emit(Op::Ret);
                } else {
                    let j = self.emit(Op::Jump { t: PENDING });
                    self.loops.last_mut().unwrap().exit_patches.push(j);
                }
            }
            St::Continue => {
                if self.loops.is_empty() {
                    self.emit(Op::Ret);
                } else {
                    let j = self.emit(Op::Jump { t: PENDING });
                    self.loops.last_mut().unwrap().cont_patches.push(j);
                }
            }
            St::Return => {
                self.emit(Op::Ret);
            }
            St::Expr(e) => {
                self.ex(e);
            }
            St::FbInvoke { fb, fb_id, inputs, outputs, line } => {
                let fb_r = self.ex(fb);
                self.emit(Op::CheckFb { r: fb_r, line: *line });
                for (fidx, e, copy) in inputs {
                    let mark = self.next;
                    let r = self.ex(e);
                    self.next = mark;
                    self.emit(Op::StoreFbInput {
                        fb_r,
                        fidx: *fidx,
                        src: r,
                        copy: *copy,
                    });
                }
                self.emit(Op::InvokeFbBody {
                    fb_r,
                    fb_id: *fb_id as u32,
                    line: *line,
                });
                for (fidx, lv) in outputs {
                    let mark = self.next;
                    let r = self.alloc();
                    self.emit(Op::LoadFbOutput { dst: r, fb_r, fidx: *fidx });
                    self.store_lv(lv, r, CopyMode::Auto);
                    self.next = mark;
                }
            }
        }
    }

    // --------------------------------------------------------- stores
    fn store_lv(&mut self, lv: &Lv, src: u16, copy: CopyMode) {
        match lv {
            Lv::Local(s) => {
                self.emit(Op::StoreLocal { src, slot: *s, copy });
            }
            Lv::Global(g) => {
                self.emit(Op::StoreGlobal { src, g: *g, copy });
            }
            Lv::SelfField(f) => {
                self.emit(Op::StoreSelf { src, f: *f, copy });
            }
            Lv::Field(base, f) => {
                let mark = self.next;
                let rb = self.ex(base);
                self.next = mark;
                self.emit(Op::StoreField { src, base: rb, f: *f, copy });
            }
            Lv::FbField(base, f) => {
                let mark = self.next;
                let rb = self.ex(base);
                self.next = mark;
                self.emit(Op::StoreFbField { src, base: rb, f: *f, copy });
            }
            Lv::Idx(base, idx, len, kind, line) => {
                let mark = self.next;
                let rb = self.ex(base);
                let ri = self.ex(idx);
                self.next = mark;
                self.emit(Op::StoreIdx {
                    src,
                    base: rb,
                    idx: ri,
                    len: *len,
                    kind: *kind,
                    line: *line,
                });
            }
            Lv::PtrAt(base, off, kind, line) => {
                let mark = self.next;
                let rp = self.ex(base);
                let roff = match off {
                    Some(o) => self.ex(o),
                    None => NO_REG,
                };
                self.next = mark;
                self.emit(Op::StorePtr {
                    src,
                    p: rp,
                    off: roff,
                    kind: *kind,
                    line: *line,
                });
            }
        }
    }

    // ---------------------------------------------------- expressions
    /// Compile an expression; the result lands in the returned temp.
    fn ex(&mut self, e: &Ex) -> u16 {
        match e {
            Ex::KBool(v) => {
                let d = self.alloc();
                self.emit(Op::ConstBool { dst: d, v: *v });
                d
            }
            Ex::KInt(v) => {
                let d = self.alloc();
                self.emit(Op::ConstInt { dst: d, v: *v });
                d
            }
            Ex::KReal(v) => {
                let d = self.alloc();
                self.emit(Op::ConstF32 { dst: d, v: *v });
                d
            }
            Ex::KLReal(v) => {
                let d = self.alloc();
                self.emit(Op::ConstF64 { dst: d, v: *v });
                d
            }
            Ex::KStr(s) => {
                let d = self.alloc();
                self.emit(Op::ConstStr { dst: d, v: s.clone() });
                d
            }
            Ex::KNull => {
                let d = self.alloc();
                self.emit(Op::ConstNull { dst: d });
                d
            }
            Ex::Local(s) => {
                let d = self.alloc();
                self.emit(Op::LoadLocal { dst: d, slot: *s });
                d
            }
            Ex::Global(g) => {
                let d = self.alloc();
                self.emit(Op::LoadGlobal { dst: d, g: *g });
                d
            }
            Ex::SelfField(f) => {
                let d = self.alloc();
                self.emit(Op::LoadSelf { dst: d, f: *f });
                d
            }
            Ex::Field(base, f) => {
                let mark = self.next;
                let rb = self.ex(base);
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::LoadField { dst: d, base: rb, f: *f });
                d
            }
            Ex::FbField(base, f) => {
                let mark = self.next;
                let rb = self.ex(base);
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::LoadFbField { dst: d, base: rb, f: *f });
                d
            }
            Ex::Idx(base, idx, len, kind, line) => {
                let mark = self.next;
                let rb = self.ex(base);
                let ri = self.ex(idx);
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::LoadIdx {
                    dst: d,
                    base: rb,
                    idx: ri,
                    len: *len,
                    kind: *kind,
                    line: *line,
                });
                d
            }
            Ex::PtrLoad(base, off, kind, line) => {
                let mark = self.next;
                let rp = self.ex(base);
                let roff = match off {
                    Some(o) => self.ex(o),
                    None => NO_REG,
                };
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::LoadPtr {
                    dst: d,
                    p: rp,
                    off: roff,
                    kind: *kind,
                    line: *line,
                });
                d
            }
            Ex::Adr(lv, kind) => self.adr(lv, *kind),
            Ex::NegF32(x) => self.unary(x, |d, s| Op::NegF32 { dst: d, src: s }),
            Ex::NegF64(x) => self.unary(x, |d, s| Op::NegF64 { dst: d, src: s }),
            Ex::NegInt(x) => self.unary(x, |d, s| Op::NegInt { dst: d, src: s }),
            Ex::Not(x) => self.unary(x, |d, s| Op::NotBool { dst: d, src: s }),
            Ex::Arith(op, kind, a, b, line) => {
                let (op, kind, line) = (*op, *kind, *line);
                self.binary(a, b, |d, ra, rb| match kind {
                    NumKind::F32 => {
                        Op::ArithF32 { op, dst: d, a: ra, b: rb, line }
                    }
                    NumKind::F64 => {
                        Op::ArithF64 { op, dst: d, a: ra, b: rb, line }
                    }
                    NumKind::Int => {
                        Op::ArithInt { op, dst: d, a: ra, b: rb, line }
                    }
                })
            }
            Ex::Cmp(op, kind, a, b) => {
                let (op, kind) = (*op, *kind);
                self.binary(a, b, |d, ra, rb| match kind {
                    NumKind::F32 => Op::CmpF32 { op, dst: d, a: ra, b: rb },
                    NumKind::F64 => Op::CmpF64 { op, dst: d, a: ra, b: rb },
                    NumKind::Int => Op::CmpInt { op, dst: d, a: ra, b: rb },
                })
            }
            Ex::CmpBool(op, a, b) => {
                let op = *op;
                self.binary(a, b, |d, ra, rb| Op::CmpBool {
                    op,
                    dst: d,
                    a: ra,
                    b: rb,
                })
            }
            Ex::BoolB(op, a, b) => {
                let op = *op;
                self.binary(a, b, |d, ra, rb| Op::BoolB {
                    op,
                    dst: d,
                    a: ra,
                    b: rb,
                })
            }
            Ex::IntB(op, a, b) => {
                let op = *op;
                self.binary(a, b, |d, ra, rb| Op::IntB {
                    op,
                    dst: d,
                    a: ra,
                    b: rb,
                })
            }
            Ex::IntToF32(x) => {
                self.unary(x, |d, s| Op::IntToF32 { dst: d, src: s })
            }
            Ex::IntToF64(x) => {
                self.unary(x, |d, s| Op::IntToF64 { dst: d, src: s })
            }
            Ex::F32ToF64(x) => {
                self.unary(x, |d, s| Op::F32ToF64 { dst: d, src: s })
            }
            Ex::F64ToF32(x) => {
                self.unary(x, |d, s| Op::F64ToF32 { dst: d, src: s })
            }
            Ex::F32ToInt(x, it) => {
                let it = *it;
                self.unary(x, move |d, s| Op::F32ToInt { dst: d, src: s, ty: it })
            }
            Ex::F64ToInt(x, it) => {
                let it = *it;
                self.unary(x, move |d, s| Op::F64ToInt { dst: d, src: s, ty: it })
            }
            Ex::IntNarrow(x, it) => {
                let it = *it;
                self.unary(x, move |d, s| Op::IntNarrow { dst: d, src: s, ty: it })
            }
            Ex::BoolToInt(x) => {
                self.unary(x, |d, s| Op::BoolToInt { dst: d, src: s })
            }
            Ex::StructLit(sid, fields) => {
                let d = self.alloc();
                self.emit(Op::StructNew { dst: d, sid: *sid as u32 });
                for (fidx, e) in fields {
                    let mark = self.next;
                    let r = self.ex(e);
                    self.next = mark;
                    self.emit(Op::StructSet { s: d, fidx: *fidx, src: r });
                }
                d
            }
            Ex::CallFn(fid, args) => {
                let mark = self.next;
                let regs: Box<[u16]> =
                    args.iter().map(|a| self.ex(a)).collect();
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::CallFn { dst: d, fid: *fid as u32, args: regs });
                d
            }
            Ex::CallMethod(fb, midx, self_e, args) => {
                let mark = self.next;
                let rs = self.ex(self_e);
                let regs: Box<[u16]> =
                    args.iter().map(|a| self.ex(a)).collect();
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::CallMethod {
                    dst: d,
                    fb: *fb as u32,
                    midx: *midx as u32,
                    self_r: rs,
                    args: regs,
                });
                d
            }
            Ex::CallIface(iid, mid, self_e, args, line) => {
                let mark = self.next;
                let rs = self.ex(self_e);
                let regs: Box<[u16]> =
                    args.iter().map(|a| self.ex(a)).collect();
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::CallIface {
                    dst: d,
                    iface: *iid as u32,
                    mid: *mid as u32,
                    self_r: rs,
                    args: regs,
                    line: *line,
                });
                d
            }
            Ex::Intrinsic(b, kind, args, line) => {
                let mark = self.next;
                let regs: Box<[u16]> =
                    args.iter().map(|a| self.ex(a)).collect();
                self.next = mark;
                let d = self.alloc();
                match b {
                    Builtin::BinArr | Builtin::ArrBin => {
                        self.emit(Op::FileIo {
                            dst: d,
                            b: *b,
                            args: regs,
                            line: *line,
                        });
                    }
                    _ => {
                        self.emit(Op::Intrinsic {
                            dst: d,
                            b: *b,
                            kind: *kind,
                            args: regs,
                        });
                    }
                }
                d
            }
        }
    }

    fn unary(&mut self, x: &Ex, make: impl FnOnce(u16, u16) -> Op) -> u16 {
        let mark = self.next;
        let rs = self.ex(x);
        self.next = mark;
        let d = self.alloc();
        self.emit(make(d, rs));
        d
    }

    fn binary(
        &mut self,
        a: &Ex,
        b: &Ex,
        make: impl FnOnce(u16, u16, u16) -> Op,
    ) -> u16 {
        let mark = self.next;
        let ra = self.ex(a);
        let rb = self.ex(b);
        self.next = mark;
        let d = self.alloc();
        self.emit(make(d, ra, rb));
        d
    }

    /// ADR(lvalue): int_ops +1 happens in the emitted Adr* op.
    fn adr(&mut self, lv: &Lv, kind: PtrKind) -> u16 {
        match lv {
            Lv::Local(s) => {
                let d = self.alloc();
                self.emit(Op::AdrLocal { dst: d, slot: *s, kind });
                d
            }
            Lv::Global(g) => {
                let d = self.alloc();
                self.emit(Op::AdrGlobal { dst: d, g: *g, kind });
                d
            }
            Lv::SelfField(f) => {
                let d = self.alloc();
                self.emit(Op::AdrSelf { dst: d, f: *f, kind });
                d
            }
            Lv::Field(base, f) => {
                let mark = self.next;
                let rb = self.ex(base);
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::AdrField { dst: d, base: rb, f: *f, kind });
                d
            }
            Lv::FbField(base, f) => {
                let mark = self.next;
                let rb = self.ex(base);
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::AdrFbField { dst: d, base: rb, f: *f, kind });
                d
            }
            Lv::Idx(base, idx, len, _, line) => {
                let mark = self.next;
                let rb = self.ex(base);
                let ri = self.ex(idx);
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::AdrIdx {
                    dst: d,
                    base: rb,
                    idx: ri,
                    len: *len,
                    kind,
                    line: *line,
                });
                d
            }
            Lv::PtrAt(base, off, _, line) => {
                let mark = self.next;
                let rp = self.ex(base);
                let roff = match off {
                    Some(o) => self.ex(o),
                    None => NO_REG,
                };
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::AdrPtr {
                    dst: d,
                    p: rp,
                    off: roff,
                    kind,
                    line: *line,
                });
                d
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn compile_src(src: &str) -> (Unit, CodeUnit) {
        let unit = crate::st::compile(src).expect("compile");
        let code = compile_unit(&unit);
        (unit, code)
    }

    #[test]
    fn compiles_flat_ops_with_resolved_jumps() {
        let (_, code) = compile_src(
            "PROGRAM p VAR i, s : DINT; END_VAR\n\
             FOR i := 0 TO 9 DO\n\
               IF i MOD 2 = 0 THEN s := s + i; END_IF\n\
             END_FOR\n\
             END_PROGRAM",
        );
        let ops = &code.programs[0].ops;
        assert!(matches!(ops.last(), Some(Op::Ret)));
        // Every jump target must land inside the op stream.
        let n = ops.len() as u32;
        for op in ops {
            match op {
                Op::Jump { t }
                | Op::JumpIfFalse { t, .. }
                | Op::CaseJump { t, .. }
                | Op::ForCheck { exit: t, .. }
                | Op::FusedForHead { exit: t, .. }
                | Op::FusedForIncrJump { t, .. }
                | Op::FusedIfCmpF32Br { t, .. } => {
                    // Every patched target lands strictly inside the
                    // stream (the trailing Ret follows all patch
                    // points); the PENDING placeholder (u32::MAX)
                    // would fail this, catching unpatched jumps.
                    assert!(*t < n, "unpatched or wild jump target {t}");
                }
                _ => {}
            }
        }
        // Program loop variables live in self fields, so the FOR head
        // stays unfused; the increment + back-edge pair fuses.
        assert!(ops.iter().any(|o| matches!(o, Op::ForCheck { .. })));
        assert!(ops
            .iter()
            .any(|o| matches!(o, Op::FusedForIncrJump { .. })));
    }

    #[test]
    fn frame_width_covers_slots_and_temps() {
        let (unit, code) = compile_src(
            "FUNCTION f : REAL VAR_INPUT a, b, c : REAL; END_VAR\n\
             f := a * b + b * c + a * c;\n\
             END_FUNCTION\n\
             PROGRAM p VAR x : REAL; END_VAR x := f(1.0, 2.0, 3.0); END_PROGRAM",
        );
        let f = &code.funcs[0];
        assert!(f.n_regs as usize > unit.funcs[0].slots.len());
    }

    #[test]
    fn case_compiles_to_range_dispatch() {
        let (_, code) = compile_src(
            "PROGRAM p VAR x : DINT; END_VAR\n\
             CASE x OF 0..4: x := 1; 7: x := 2; ELSE x := 3; END_CASE\n\
             END_PROGRAM",
        );
        let ops = &code.programs[0].ops;
        let cases: Vec<_> = ops
            .iter()
            .filter_map(|o| match o {
                Op::CaseJump { ranges, .. } => Some(ranges.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(cases.len(), 2);
        assert_eq!(*cases[0], vec![(0, 4)]);
        assert_eq!(*cases[1], vec![(7, 7)]);
    }

    /// The dense-kernel shapes the fusion pass exists for: a
    /// DOT_PRODUCT-shaped function and a pruned-row MAC walk.
    const DENSE_SRC: &str = "FUNCTION DOT : REAL\n\
         VAR_INPUT pa : POINTER TO REAL; pb : POINTER TO REAL; n : DINT; END_VAR\n\
         VAR s : REAL; i : DINT; END_VAR\n\
         FOR i := 0 TO n - 1 DO\n\
           s := s + pa[i] * pb[i];\n\
         END_FOR\n\
         DOT := s;\n\
         END_FUNCTION\n\
         FUNCTION ROWMAC : REAL\n\
         VAR_INPUT pw : POINTER TO REAL; px : POINTER TO REAL; ncols : DINT; row : DINT; END_VAR\n\
         VAR s, wv : REAL; i : DINT; END_VAR\n\
         FOR i := 0 TO ncols - 1 DO\n\
           wv := pw[row * ncols + i];\n\
           IF wv <> 0.0 THEN\n\
             s := s + wv * px[i];\n\
           END_IF\n\
         END_FOR\n\
         ROWMAC := s;\n\
         END_FUNCTION\n\
         PROGRAM p\n\
         VAR a, b : ARRAY[0..7] OF REAL; r1, r2 : REAL; i : DINT; END_VAR\n\
         FOR i := 0 TO 7 DO\n\
           a[i] := DINT_TO_REAL(i) * 0.25;\n\
           b[i] := 2.0 - DINT_TO_REAL(i) * 0.25;\n\
         END_FOR\n\
         r1 := DOT(ADR(a), ADR(b), 8);\n\
         r2 := ROWMAC(ADR(a), ADR(b), 4, 1);\n\
         END_PROGRAM";

    #[test]
    fn fusion_off_is_byte_identical_to_compile_fn() {
        let unit = crate::st::compile(DENSE_SRC).expect("compile");
        let off =
            compile_unit_with(&unit, &FusionConfig { enabled: false });
        let manual = CodeUnit {
            funcs: unit.funcs.iter().map(compile_fn).collect(),
            fb_methods: unit
                .fbs
                .iter()
                .map(|fb| fb.methods.iter().map(compile_fn).collect())
                .collect(),
            fb_bodies: unit
                .fbs
                .iter()
                .map(|fb| fb.body.as_ref().map(compile_fn))
                .collect(),
            programs: unit
                .programs
                .iter()
                .map(|p| compile_fn(&p.body))
                .collect(),
        };
        assert_eq!(format!("{manual:?}"), format!("{off:?}"));
        assert_eq!(off.fused_ops(), 0);
        assert!(off.all_codes().all(|c| c.pool.is_empty()));
    }

    #[test]
    fn dense_kernel_shapes_fuse() {
        let (_, cu) = compile_src(DENSE_SRC);
        let has = |pred: &dyn Fn(&Op) -> bool| {
            cu.all_codes().any(|c| c.ops.iter().any(pred))
        };
        assert!(has(&|o| matches!(o, Op::FusedDotStep { .. })));
        assert!(has(&|o| matches!(o, Op::FusedForHead { .. })));
        assert!(has(&|o| matches!(o, Op::FusedForIncrJump { .. })));
        assert!(has(&|o| matches!(o, Op::FusedMacStep { .. })));
        assert!(
            has(&|o| matches!(o, Op::FusedMacLoad { b_self: false, .. }))
        );
        assert!(has(&|o| matches!(o, Op::FusedIfCmpF32Br { .. })));
    }

    #[test]
    fn constant_pool_is_deduplicated() {
        let (_, cu) = compile_src(
            "PROGRAM p VAR x, y : REAL; i : DINT; END_VAR\n\
             x := 1.5; y := 1.5 + 1.5; i := 3 + 3 + 3;\n\
             END_PROGRAM",
        );
        let code = &cu.programs[0];
        assert!(!code.pool.is_empty());
        let mut seen = std::collections::HashSet::new();
        for k in &code.pool {
            let key = match k {
                Konst::Int(v) => format!("i{v}"),
                Konst::F32(v) => format!("f{:08x}", v.to_bits()),
                Konst::F64(v) => format!("d{:016x}", v.to_bits()),
                Konst::Str(s) => format!("s{s}"),
            };
            assert!(seen.insert(key), "duplicate pool entry {k:?}");
        }
        let n_pool = code.pool.len() as u32;
        for op in &code.ops {
            if let Op::ConstPool { idx, .. } = op {
                assert!(*idx < n_pool);
            }
            assert!(!matches!(
                op,
                Op::ConstInt { .. }
                    | Op::ConstF32 { .. }
                    | Op::ConstF64 { .. }
                    | Op::ConstStr { .. }
            ));
        }
    }

    #[test]
    fn coalescing_shrinks_fused_frames() {
        let unit = crate::st::compile(DENSE_SRC).expect("compile");
        let fused = compile_unit_with(&unit, &FusionConfig::default());
        let plain =
            compile_unit_with(&unit, &FusionConfig { enabled: false });
        let dot_fused = &fused.funcs[0];
        let dot_plain = &plain.funcs[0];
        assert!(
            dot_fused.n_regs < dot_plain.n_regs,
            "fusion should free dot-step temps ({} vs {})",
            dot_fused.n_regs,
            dot_plain.n_regs
        );
    }

    #[test]
    fn registers_stay_in_bounds_fused_and_plain() {
        let unit = crate::st::compile(DENSE_SRC).expect("compile");
        for cfg in [
            FusionConfig { enabled: true },
            FusionConfig { enabled: false },
        ] {
            let cu = compile_unit_with(&unit, &cfg);
            for code in cu.all_codes() {
                let name = code.name.clone();
                let n = code.n_regs;
                let mut c = code.clone();
                for op in &mut c.ops {
                    for_each_reg(op, &mut |r| {
                        assert!(
                            *r == NO_REG || *r < n,
                            "register {} out of bounds in {name}",
                            *r
                        );
                    });
                }
            }
        }
    }
}
