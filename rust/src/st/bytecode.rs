//! Register bytecode for the ST runtime — the compiled tier.
//!
//! [`super::lower`] already resolves every name, type and slot; this
//! module performs the *second*, mechanical lowering: the [`ir`] tree
//! becomes a flat, register-addressed instruction stream with resolved
//! jump targets. [`super::vm::Vm`] executes it over a contiguous
//! register arena; [`super::interp::Interp`] remains the reference
//! oracle.
//!
//! Register model: each POU body gets a frame of `n_regs` registers.
//! Registers `0..n_slots` *are* the IR frame slots (slot 0 = return
//! value); registers above the slots are expression temporaries
//! assigned by a watermark allocator, so a statement's temps are dead
//! at the next statement boundary.
//!
//! Meter discipline (the hard requirement): every opcode applies
//! exactly the [`super::cost::Meter`] increments the tree-walker
//! applies for the IR node(s) it encodes, so a successful execution
//! meters **identically** on both tiers — the PLC timing model
//! (`plc/profiles.rs`) depends on it, and `tests/st_differential.rs`
//! enforces it. The one tolerated divergence: when execution aborts
//! with a runtime error mid-statement, the two tiers may disagree on
//! counters *after* the already-divergent failure point (the interp
//! pre-bumps some counters before evaluating operands; the VM has
//! already evaluated operands when the op runs). Error programs
//! must still fail on both tiers.

use std::sync::Arc;

use super::ir::*;

/// Sentinel register meaning "no operand" (e.g. `p^` with no offset).
pub const NO_REG: u16 = u16::MAX;

/// Placeholder for a jump target that is patched before `compile_fn`
/// returns. Deliberately out of range (never a valid pc): a bug that
/// leaves one unpatched indexes past the op stream and fails fast
/// instead of silently jumping to pc 0.
const PENDING: u32 = u32::MAX;

/// How a store treats its value, mirroring `Interp::assign`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CopyMode {
    /// Move the handle/value (scalar assignment).
    Move,
    /// Deep-copy into the destination's storage, metering bytes.
    Copy,
    /// Copy iff the runtime value is an aggregate (FB output binding —
    /// the interp decides by inspecting the value).
    Auto,
}

/// One instruction. `dst`/`a`/`b`/... address registers relative to
/// the executing frame's base; indices into the [`Unit`] (functions,
/// FBs, structs) are resolved at compile time.
#[derive(Debug, Clone)]
pub enum Op {
    // ------------------------------------------------------ constants
    ConstBool { dst: u16, v: bool },
    ConstInt { dst: u16, v: i64 },
    ConstF32 { dst: u16, v: f32 },
    ConstF64 { dst: u16, v: f64 },
    ConstStr { dst: u16, v: Arc<str> },
    ConstNull { dst: u16 },
    /// Unmetered register copy (loop-variable materialization).
    Mov { dst: u16, src: u16 },

    // ----------------------------------------------- reads (loads +1)
    LoadLocal { dst: u16, slot: u16 },
    LoadGlobal { dst: u16, g: u16 },
    LoadSelf { dst: u16, f: u16 },
    LoadField { dst: u16, base: u16, f: u16 },
    LoadFbField { dst: u16, base: u16, f: u16 },
    LoadIdx { dst: u16, base: u16, idx: u16, len: u32, kind: ElemKind, line: u32 },
    LoadPtr { dst: u16, p: u16, off: u16, kind: PtrKind, line: u32 },

    // ---------------------------------------------- ADR (int_ops +1)
    AdrLocal { dst: u16, slot: u16, kind: PtrKind },
    AdrGlobal { dst: u16, g: u16, kind: PtrKind },
    AdrSelf { dst: u16, f: u16, kind: PtrKind },
    AdrField { dst: u16, base: u16, f: u16, kind: PtrKind },
    AdrFbField { dst: u16, base: u16, f: u16, kind: PtrKind },
    AdrIdx { dst: u16, base: u16, idx: u16, len: u32, kind: PtrKind, line: u32 },
    AdrPtr { dst: u16, p: u16, off: u16, kind: PtrKind, line: u32 },

    // ---------------------------------------------------------- unary
    NegF32 { dst: u16, src: u16 },
    NegF64 { dst: u16, src: u16 },
    NegInt { dst: u16, src: u16 },
    NotBool { dst: u16, src: u16 },

    // ------------------------- arithmetic, specialized per repr kind
    ArithF32 { op: ArithOp, dst: u16, a: u16, b: u16, line: u32 },
    ArithF64 { op: ArithOp, dst: u16, a: u16, b: u16, line: u32 },
    ArithInt { op: ArithOp, dst: u16, a: u16, b: u16, line: u32 },
    CmpF32 { op: CmpOp, dst: u16, a: u16, b: u16 },
    CmpF64 { op: CmpOp, dst: u16, a: u16, b: u16 },
    CmpInt { op: CmpOp, dst: u16, a: u16, b: u16 },
    CmpBool { op: CmpOp, dst: u16, a: u16, b: u16 },
    BoolB { op: BoolOp, dst: u16, a: u16, b: u16 },
    IntB { op: BoolOp, dst: u16, a: u16, b: u16 },

    // ------------------------------------- conversions (converts +1)
    IntToF32 { dst: u16, src: u16 },
    IntToF64 { dst: u16, src: u16 },
    F32ToF64 { dst: u16, src: u16 },
    F64ToF32 { dst: u16, src: u16 },
    F32ToInt { dst: u16, src: u16, ty: IntTy },
    F64ToInt { dst: u16, src: u16, ty: IntTy },
    IntNarrow { dst: u16, src: u16, ty: IntTy },
    BoolToInt { dst: u16, src: u16 },

    // ---------------------------------------------------------- calls
    CallFn { dst: u16, fid: u32, args: Box<[u16]> },
    CallMethod { dst: u16, fb: u32, midx: u32, self_r: u16, args: Box<[u16]> },
    CallIface {
        dst: u16,
        iface: u32,
        mid: u32,
        self_r: u16,
        args: Box<[u16]>,
        line: u32,
    },
    /// Validate the FB reference of an `inst(...)` invocation before
    /// its inputs are stored (the interp errors at this point).
    CheckFb { r: u16, line: u32 },
    InvokeFbBody { fb_r: u16, fb_id: u32, line: u32 },
    /// FB-invocation input binding: `store_field` semantics
    /// (stores +1, copy bytes metered when `copy`).
    StoreFbInput { fb_r: u16, fidx: u16, src: u16, copy: bool },
    /// FB-invocation output read: unmetered field clone.
    LoadFbOutput { dst: u16, fb_r: u16, fidx: u16 },

    // ------------------------------------------------- struct literal
    StructNew { dst: u16, sid: u32 },
    StructSet { s: u16, fidx: u16, src: u16 },

    // ------------------------------------------------------ builtins
    Intrinsic { dst: u16, b: Builtin, kind: NumKind, args: Box<[u16]> },
    FileIo { dst: u16, b: Builtin, args: Box<[u16]>, line: u32 },

    // ------------------------------------------------------- stores
    StoreLocal { src: u16, slot: u16, copy: CopyMode },
    StoreGlobal { src: u16, g: u16, copy: CopyMode },
    /// stores +2: `Interp::assign` bumps once, then delegates to
    /// `store_field`, which bumps again. Quirk preserved bit-for-bit.
    StoreSelf { src: u16, f: u16, copy: CopyMode },
    StoreField { src: u16, base: u16, f: u16, copy: CopyMode },
    /// stores +2 — same double-bump as [`Op::StoreSelf`].
    StoreFbField { src: u16, base: u16, f: u16, copy: CopyMode },
    StoreIdx { src: u16, base: u16, idx: u16, len: u32, kind: ElemKind, line: u32 },
    StorePtr { src: u16, p: u16, off: u16, kind: PtrKind, line: u32 },

    // ------------------------------------------------- control flow
    Jump { t: u32 },
    JumpIfFalse { c: u16, t: u32 },
    /// branches +1 (IF / CASE / WHILE / REPEAT decision points).
    BumpBranch,
    /// Jump to `t` when the scrutinee falls in any range (unmetered,
    /// like the interp's label scan).
    CaseJump { src: u16, ranges: Arc<Vec<(i64, i64)>>, t: u32 },
    /// FOR head: jump to `exit` when done (unmetered, matching the
    /// interp's loop-condition test); otherwise branches +1.
    ForCheck { i: u16, to: u16, step: u16, exit: u32 },
    /// int_ops +1; `i += step` (wrapping).
    ForIncr { i: u16, step: u16 },
    /// Errors with "FOR step of 0" like the interp's pre-loop check.
    ForStepCheck { step: u16 },
    Ret,
}

/// A compiled POU body.
#[derive(Debug, Clone)]
pub struct Code {
    pub name: String,
    /// Frame width: IR slots first, expression temps above.
    pub n_regs: u16,
    pub ops: Vec<Op>,
}

/// Compiled bytecode for a whole [`Unit`], indexed in parallel with
/// the unit's own tables.
#[derive(Debug, Default, Clone)]
pub struct CodeUnit {
    pub funcs: Vec<Code>,
    /// `fb_methods[fb_id][method_idx]`.
    pub fb_methods: Vec<Vec<Code>>,
    pub fb_bodies: Vec<Option<Code>>,
    pub programs: Vec<Code>,
}

/// Compile every POU body in the unit.
pub fn compile_unit(unit: &Unit) -> CodeUnit {
    CodeUnit {
        funcs: unit.funcs.iter().map(compile_fn).collect(),
        fb_methods: unit
            .fbs
            .iter()
            .map(|fb| fb.methods.iter().map(compile_fn).collect())
            .collect(),
        fb_bodies: unit
            .fbs
            .iter()
            .map(|fb| fb.body.as_ref().map(compile_fn))
            .collect(),
        programs: unit.programs.iter().map(|p| compile_fn(&p.body)).collect(),
    }
}

// Register-file size is a static program-size limit, not a runtime
// condition: slot indices are u16 in the IR itself, and the temp
// watermark only exceeds u16 on a ~65k-deep right-nested expression —
// which the recursive lowerer cannot produce without exhausting its own
// stack first. Treated like the other static IEC limits (panic with
// the POU named), not plumbed through as a typed error.
fn compile_fn(fd: &FuncDef) -> Code {
    let n_slots = fd.slots.len();
    assert!(n_slots < NO_REG as usize, "{}: too many slots", fd.name);
    let mut fc = Fc {
        ops: Vec::new(),
        next: n_slots as u16,
        max: n_slots as u16,
        loops: Vec::new(),
    };
    fc.block(&fd.body);
    fc.ops.push(Op::Ret);
    Code { name: fd.name.clone(), n_regs: fc.max, ops: fc.ops }
}

#[derive(Default)]
struct LoopFrame {
    exit_patches: Vec<usize>,
    cont_patches: Vec<usize>,
}

/// Per-body compiler state.
struct Fc {
    ops: Vec<Op>,
    /// Watermark temp allocator: next free register.
    next: u16,
    max: u16,
    loops: Vec<LoopFrame>,
}

impl Fc {
    fn alloc(&mut self) -> u16 {
        let r = self.next;
        self.next = self
            .next
            .checked_add(1)
            .filter(|&n| n < NO_REG)
            .expect("register file overflow");
        if self.next > self.max {
            self.max = self.next;
        }
        r
    }

    fn emit(&mut self, op: Op) -> usize {
        self.ops.push(op);
        self.ops.len() - 1
    }

    fn here(&self) -> u32 {
        self.ops.len() as u32
    }

    fn patch(&mut self, idx: usize, target: u32) {
        match &mut self.ops[idx] {
            Op::Jump { t }
            | Op::JumpIfFalse { t, .. }
            | Op::CaseJump { t, .. }
            | Op::ForCheck { exit: t, .. } => *t = target,
            other => unreachable!("patching non-jump {other:?}"),
        }
    }

    fn block(&mut self, body: &[St]) {
        for st in body {
            let mark = self.next;
            self.stmt(st);
            self.next = mark;
        }
    }

    // ------------------------------------------------------ statements
    fn stmt(&mut self, st: &St) {
        match st {
            St::Assign(lv, e, copy) => {
                let r = self.ex(e);
                let mode = if *copy { CopyMode::Copy } else { CopyMode::Move };
                self.store_lv(lv, r, mode);
            }
            St::If(arms, else_body) => {
                self.emit(Op::BumpBranch);
                let mut end_patches = Vec::new();
                for (cond, body) in arms {
                    let mark = self.next;
                    let rc = self.ex(cond);
                    self.next = mark;
                    let jf = self.emit(Op::JumpIfFalse { c: rc, t: PENDING });
                    self.block(body);
                    end_patches.push(self.emit(Op::Jump { t: PENDING }));
                    let after = self.here();
                    self.patch(jf, after);
                }
                self.block(else_body);
                let end = self.here();
                for p in end_patches {
                    self.patch(p, end);
                }
            }
            St::Case(scrut, arms, else_body) => {
                self.emit(Op::BumpBranch);
                let mark = self.next;
                let rs = self.ex(scrut);
                let mut arm_jumps = Vec::new();
                for (ranges, _) in arms {
                    arm_jumps.push(self.emit(Op::CaseJump {
                        src: rs,
                        ranges: ranges.clone(),
                        t: PENDING,
                    }));
                }
                let else_jump = self.emit(Op::Jump { t: PENDING });
                self.next = mark;
                let mut end_patches = Vec::new();
                for (j, (_, body)) in arms.iter().enumerate() {
                    let here = self.here();
                    self.patch(arm_jumps[j], here);
                    self.block(body);
                    end_patches.push(self.emit(Op::Jump { t: PENDING }));
                }
                let else_at = self.here();
                self.patch(else_jump, else_at);
                self.block(else_body);
                let end = self.here();
                for p in end_patches {
                    self.patch(p, end);
                }
            }
            St::For { var, from, to, by, body } => {
                // Loop registers live for the whole statement.
                let ri = self.ex(from);
                let rto = self.ex(to);
                let rstep = match by {
                    Some(b) => self.ex(b),
                    None => {
                        let d = self.alloc();
                        self.emit(Op::ConstInt { dst: d, v: 1 });
                        d
                    }
                };
                let rtmp = self.alloc();
                self.emit(Op::ForStepCheck { step: rstep });
                let head = self.here();
                let fc =
                    self.emit(Op::ForCheck { i: ri, to: rto, step: rstep, exit: PENDING });
                self.emit(Op::Mov { dst: rtmp, src: ri });
                let mark = self.next;
                self.store_lv(var, rtmp, CopyMode::Move);
                self.next = mark;
                self.loops.push(LoopFrame::default());
                self.block(body);
                let lf = self.loops.pop().unwrap();
                let cont = self.here();
                for p in lf.cont_patches {
                    self.patch(p, cont);
                }
                self.emit(Op::ForIncr { i: ri, step: rstep });
                self.emit(Op::Jump { t: head });
                let exit = self.here();
                self.patch(fc, exit);
                for p in lf.exit_patches {
                    self.patch(p, exit);
                }
            }
            St::While(cond, body) => {
                let head = self.here();
                self.emit(Op::BumpBranch);
                let mark = self.next;
                let rc = self.ex(cond);
                self.next = mark;
                let jf = self.emit(Op::JumpIfFalse { c: rc, t: PENDING });
                self.loops.push(LoopFrame::default());
                self.block(body);
                let lf = self.loops.pop().unwrap();
                for p in lf.cont_patches {
                    self.patch(p, head);
                }
                self.emit(Op::Jump { t: head });
                let exit = self.here();
                self.patch(jf, exit);
                for p in lf.exit_patches {
                    self.patch(p, exit);
                }
            }
            St::Repeat(body, until) => {
                let top = self.here();
                self.loops.push(LoopFrame::default());
                self.block(body);
                let lf = self.loops.pop().unwrap();
                let cont = self.here();
                for p in lf.cont_patches {
                    self.patch(p, cont);
                }
                self.emit(Op::BumpBranch);
                let mark = self.next;
                let ru = self.ex(until);
                self.next = mark;
                self.emit(Op::JumpIfFalse { c: ru, t: top });
                let exit = self.here();
                for p in lf.exit_patches {
                    self.patch(p, exit);
                }
            }
            // EXIT/CONTINUE outside a loop end the POU (the interp's
            // Flow propagates to run_func); lower rejects them anyway.
            St::Exit => {
                if self.loops.is_empty() {
                    self.emit(Op::Ret);
                } else {
                    let j = self.emit(Op::Jump { t: PENDING });
                    self.loops.last_mut().unwrap().exit_patches.push(j);
                }
            }
            St::Continue => {
                if self.loops.is_empty() {
                    self.emit(Op::Ret);
                } else {
                    let j = self.emit(Op::Jump { t: PENDING });
                    self.loops.last_mut().unwrap().cont_patches.push(j);
                }
            }
            St::Return => {
                self.emit(Op::Ret);
            }
            St::Expr(e) => {
                self.ex(e);
            }
            St::FbInvoke { fb, fb_id, inputs, outputs, line } => {
                let fb_r = self.ex(fb);
                self.emit(Op::CheckFb { r: fb_r, line: *line });
                for (fidx, e, copy) in inputs {
                    let mark = self.next;
                    let r = self.ex(e);
                    self.next = mark;
                    self.emit(Op::StoreFbInput {
                        fb_r,
                        fidx: *fidx,
                        src: r,
                        copy: *copy,
                    });
                }
                self.emit(Op::InvokeFbBody {
                    fb_r,
                    fb_id: *fb_id as u32,
                    line: *line,
                });
                for (fidx, lv) in outputs {
                    let mark = self.next;
                    let r = self.alloc();
                    self.emit(Op::LoadFbOutput { dst: r, fb_r, fidx: *fidx });
                    self.store_lv(lv, r, CopyMode::Auto);
                    self.next = mark;
                }
            }
        }
    }

    // --------------------------------------------------------- stores
    fn store_lv(&mut self, lv: &Lv, src: u16, copy: CopyMode) {
        match lv {
            Lv::Local(s) => {
                self.emit(Op::StoreLocal { src, slot: *s, copy });
            }
            Lv::Global(g) => {
                self.emit(Op::StoreGlobal { src, g: *g, copy });
            }
            Lv::SelfField(f) => {
                self.emit(Op::StoreSelf { src, f: *f, copy });
            }
            Lv::Field(base, f) => {
                let mark = self.next;
                let rb = self.ex(base);
                self.next = mark;
                self.emit(Op::StoreField { src, base: rb, f: *f, copy });
            }
            Lv::FbField(base, f) => {
                let mark = self.next;
                let rb = self.ex(base);
                self.next = mark;
                self.emit(Op::StoreFbField { src, base: rb, f: *f, copy });
            }
            Lv::Idx(base, idx, len, kind, line) => {
                let mark = self.next;
                let rb = self.ex(base);
                let ri = self.ex(idx);
                self.next = mark;
                self.emit(Op::StoreIdx {
                    src,
                    base: rb,
                    idx: ri,
                    len: *len,
                    kind: *kind,
                    line: *line,
                });
            }
            Lv::PtrAt(base, off, kind, line) => {
                let mark = self.next;
                let rp = self.ex(base);
                let roff = match off {
                    Some(o) => self.ex(o),
                    None => NO_REG,
                };
                self.next = mark;
                self.emit(Op::StorePtr {
                    src,
                    p: rp,
                    off: roff,
                    kind: *kind,
                    line: *line,
                });
            }
        }
    }

    // ---------------------------------------------------- expressions
    /// Compile an expression; the result lands in the returned temp.
    fn ex(&mut self, e: &Ex) -> u16 {
        match e {
            Ex::KBool(v) => {
                let d = self.alloc();
                self.emit(Op::ConstBool { dst: d, v: *v });
                d
            }
            Ex::KInt(v) => {
                let d = self.alloc();
                self.emit(Op::ConstInt { dst: d, v: *v });
                d
            }
            Ex::KReal(v) => {
                let d = self.alloc();
                self.emit(Op::ConstF32 { dst: d, v: *v });
                d
            }
            Ex::KLReal(v) => {
                let d = self.alloc();
                self.emit(Op::ConstF64 { dst: d, v: *v });
                d
            }
            Ex::KStr(s) => {
                let d = self.alloc();
                self.emit(Op::ConstStr { dst: d, v: s.clone() });
                d
            }
            Ex::KNull => {
                let d = self.alloc();
                self.emit(Op::ConstNull { dst: d });
                d
            }
            Ex::Local(s) => {
                let d = self.alloc();
                self.emit(Op::LoadLocal { dst: d, slot: *s });
                d
            }
            Ex::Global(g) => {
                let d = self.alloc();
                self.emit(Op::LoadGlobal { dst: d, g: *g });
                d
            }
            Ex::SelfField(f) => {
                let d = self.alloc();
                self.emit(Op::LoadSelf { dst: d, f: *f });
                d
            }
            Ex::Field(base, f) => {
                let mark = self.next;
                let rb = self.ex(base);
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::LoadField { dst: d, base: rb, f: *f });
                d
            }
            Ex::FbField(base, f) => {
                let mark = self.next;
                let rb = self.ex(base);
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::LoadFbField { dst: d, base: rb, f: *f });
                d
            }
            Ex::Idx(base, idx, len, kind, line) => {
                let mark = self.next;
                let rb = self.ex(base);
                let ri = self.ex(idx);
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::LoadIdx {
                    dst: d,
                    base: rb,
                    idx: ri,
                    len: *len,
                    kind: *kind,
                    line: *line,
                });
                d
            }
            Ex::PtrLoad(base, off, kind, line) => {
                let mark = self.next;
                let rp = self.ex(base);
                let roff = match off {
                    Some(o) => self.ex(o),
                    None => NO_REG,
                };
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::LoadPtr {
                    dst: d,
                    p: rp,
                    off: roff,
                    kind: *kind,
                    line: *line,
                });
                d
            }
            Ex::Adr(lv, kind) => self.adr(lv, *kind),
            Ex::NegF32(x) => self.unary(x, |d, s| Op::NegF32 { dst: d, src: s }),
            Ex::NegF64(x) => self.unary(x, |d, s| Op::NegF64 { dst: d, src: s }),
            Ex::NegInt(x) => self.unary(x, |d, s| Op::NegInt { dst: d, src: s }),
            Ex::Not(x) => self.unary(x, |d, s| Op::NotBool { dst: d, src: s }),
            Ex::Arith(op, kind, a, b, line) => {
                let (op, kind, line) = (*op, *kind, *line);
                self.binary(a, b, |d, ra, rb| match kind {
                    NumKind::F32 => {
                        Op::ArithF32 { op, dst: d, a: ra, b: rb, line }
                    }
                    NumKind::F64 => {
                        Op::ArithF64 { op, dst: d, a: ra, b: rb, line }
                    }
                    NumKind::Int => {
                        Op::ArithInt { op, dst: d, a: ra, b: rb, line }
                    }
                })
            }
            Ex::Cmp(op, kind, a, b) => {
                let (op, kind) = (*op, *kind);
                self.binary(a, b, |d, ra, rb| match kind {
                    NumKind::F32 => Op::CmpF32 { op, dst: d, a: ra, b: rb },
                    NumKind::F64 => Op::CmpF64 { op, dst: d, a: ra, b: rb },
                    NumKind::Int => Op::CmpInt { op, dst: d, a: ra, b: rb },
                })
            }
            Ex::CmpBool(op, a, b) => {
                let op = *op;
                self.binary(a, b, |d, ra, rb| Op::CmpBool {
                    op,
                    dst: d,
                    a: ra,
                    b: rb,
                })
            }
            Ex::BoolB(op, a, b) => {
                let op = *op;
                self.binary(a, b, |d, ra, rb| Op::BoolB {
                    op,
                    dst: d,
                    a: ra,
                    b: rb,
                })
            }
            Ex::IntB(op, a, b) => {
                let op = *op;
                self.binary(a, b, |d, ra, rb| Op::IntB {
                    op,
                    dst: d,
                    a: ra,
                    b: rb,
                })
            }
            Ex::IntToF32(x) => {
                self.unary(x, |d, s| Op::IntToF32 { dst: d, src: s })
            }
            Ex::IntToF64(x) => {
                self.unary(x, |d, s| Op::IntToF64 { dst: d, src: s })
            }
            Ex::F32ToF64(x) => {
                self.unary(x, |d, s| Op::F32ToF64 { dst: d, src: s })
            }
            Ex::F64ToF32(x) => {
                self.unary(x, |d, s| Op::F64ToF32 { dst: d, src: s })
            }
            Ex::F32ToInt(x, it) => {
                let it = *it;
                self.unary(x, move |d, s| Op::F32ToInt { dst: d, src: s, ty: it })
            }
            Ex::F64ToInt(x, it) => {
                let it = *it;
                self.unary(x, move |d, s| Op::F64ToInt { dst: d, src: s, ty: it })
            }
            Ex::IntNarrow(x, it) => {
                let it = *it;
                self.unary(x, move |d, s| Op::IntNarrow { dst: d, src: s, ty: it })
            }
            Ex::BoolToInt(x) => {
                self.unary(x, |d, s| Op::BoolToInt { dst: d, src: s })
            }
            Ex::StructLit(sid, fields) => {
                let d = self.alloc();
                self.emit(Op::StructNew { dst: d, sid: *sid as u32 });
                for (fidx, e) in fields {
                    let mark = self.next;
                    let r = self.ex(e);
                    self.next = mark;
                    self.emit(Op::StructSet { s: d, fidx: *fidx, src: r });
                }
                d
            }
            Ex::CallFn(fid, args) => {
                let mark = self.next;
                let regs: Box<[u16]> =
                    args.iter().map(|a| self.ex(a)).collect();
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::CallFn { dst: d, fid: *fid as u32, args: regs });
                d
            }
            Ex::CallMethod(fb, midx, self_e, args) => {
                let mark = self.next;
                let rs = self.ex(self_e);
                let regs: Box<[u16]> =
                    args.iter().map(|a| self.ex(a)).collect();
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::CallMethod {
                    dst: d,
                    fb: *fb as u32,
                    midx: *midx as u32,
                    self_r: rs,
                    args: regs,
                });
                d
            }
            Ex::CallIface(iid, mid, self_e, args, line) => {
                let mark = self.next;
                let rs = self.ex(self_e);
                let regs: Box<[u16]> =
                    args.iter().map(|a| self.ex(a)).collect();
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::CallIface {
                    dst: d,
                    iface: *iid as u32,
                    mid: *mid as u32,
                    self_r: rs,
                    args: regs,
                    line: *line,
                });
                d
            }
            Ex::Intrinsic(b, kind, args, line) => {
                let mark = self.next;
                let regs: Box<[u16]> =
                    args.iter().map(|a| self.ex(a)).collect();
                self.next = mark;
                let d = self.alloc();
                match b {
                    Builtin::BinArr | Builtin::ArrBin => {
                        self.emit(Op::FileIo {
                            dst: d,
                            b: *b,
                            args: regs,
                            line: *line,
                        });
                    }
                    _ => {
                        self.emit(Op::Intrinsic {
                            dst: d,
                            b: *b,
                            kind: *kind,
                            args: regs,
                        });
                    }
                }
                d
            }
        }
    }

    fn unary(&mut self, x: &Ex, make: impl FnOnce(u16, u16) -> Op) -> u16 {
        let mark = self.next;
        let rs = self.ex(x);
        self.next = mark;
        let d = self.alloc();
        self.emit(make(d, rs));
        d
    }

    fn binary(
        &mut self,
        a: &Ex,
        b: &Ex,
        make: impl FnOnce(u16, u16, u16) -> Op,
    ) -> u16 {
        let mark = self.next;
        let ra = self.ex(a);
        let rb = self.ex(b);
        self.next = mark;
        let d = self.alloc();
        self.emit(make(d, ra, rb));
        d
    }

    /// ADR(lvalue): int_ops +1 happens in the emitted Adr* op.
    fn adr(&mut self, lv: &Lv, kind: PtrKind) -> u16 {
        match lv {
            Lv::Local(s) => {
                let d = self.alloc();
                self.emit(Op::AdrLocal { dst: d, slot: *s, kind });
                d
            }
            Lv::Global(g) => {
                let d = self.alloc();
                self.emit(Op::AdrGlobal { dst: d, g: *g, kind });
                d
            }
            Lv::SelfField(f) => {
                let d = self.alloc();
                self.emit(Op::AdrSelf { dst: d, f: *f, kind });
                d
            }
            Lv::Field(base, f) => {
                let mark = self.next;
                let rb = self.ex(base);
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::AdrField { dst: d, base: rb, f: *f, kind });
                d
            }
            Lv::FbField(base, f) => {
                let mark = self.next;
                let rb = self.ex(base);
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::AdrFbField { dst: d, base: rb, f: *f, kind });
                d
            }
            Lv::Idx(base, idx, len, _, line) => {
                let mark = self.next;
                let rb = self.ex(base);
                let ri = self.ex(idx);
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::AdrIdx {
                    dst: d,
                    base: rb,
                    idx: ri,
                    len: *len,
                    kind,
                    line: *line,
                });
                d
            }
            Lv::PtrAt(base, off, _, line) => {
                let mark = self.next;
                let rp = self.ex(base);
                let roff = match off {
                    Some(o) => self.ex(o),
                    None => NO_REG,
                };
                self.next = mark;
                let d = self.alloc();
                self.emit(Op::AdrPtr {
                    dst: d,
                    p: rp,
                    off: roff,
                    kind,
                    line: *line,
                });
                d
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn compile_src(src: &str) -> (Unit, CodeUnit) {
        let unit = crate::st::compile(src).expect("compile");
        let code = compile_unit(&unit);
        (unit, code)
    }

    #[test]
    fn compiles_flat_ops_with_resolved_jumps() {
        let (_, code) = compile_src(
            "PROGRAM p VAR i, s : DINT; END_VAR\n\
             FOR i := 0 TO 9 DO\n\
               IF i MOD 2 = 0 THEN s := s + i; END_IF\n\
             END_FOR\n\
             END_PROGRAM",
        );
        let ops = &code.programs[0].ops;
        assert!(matches!(ops.last(), Some(Op::Ret)));
        // Every jump target must land inside the op stream.
        let n = ops.len() as u32;
        for op in ops {
            match op {
                Op::Jump { t }
                | Op::JumpIfFalse { t, .. }
                | Op::CaseJump { t, .. }
                | Op::ForCheck { exit: t, .. } => {
                    // Every patched target lands strictly inside the
                    // stream (the trailing Ret follows all patch
                    // points); the PENDING placeholder (u32::MAX)
                    // would fail this, catching unpatched jumps.
                    assert!(*t < n, "unpatched or wild jump target {t}");
                }
                _ => {}
            }
        }
        assert!(ops.iter().any(|o| matches!(o, Op::ForCheck { .. })));
        assert!(ops.iter().any(|o| matches!(o, Op::ForIncr { .. })));
    }

    #[test]
    fn frame_width_covers_slots_and_temps() {
        let (unit, code) = compile_src(
            "FUNCTION f : REAL VAR_INPUT a, b, c : REAL; END_VAR\n\
             f := a * b + b * c + a * c;\n\
             END_FUNCTION\n\
             PROGRAM p VAR x : REAL; END_VAR x := f(1.0, 2.0, 3.0); END_PROGRAM",
        );
        let f = &code.funcs[0];
        assert!(f.n_regs as usize > unit.funcs[0].slots.len());
    }

    #[test]
    fn case_compiles_to_range_dispatch() {
        let (_, code) = compile_src(
            "PROGRAM p VAR x : DINT; END_VAR\n\
             CASE x OF 0..4: x := 1; 7: x := 2; ELSE x := 3; END_CASE\n\
             END_PROGRAM",
        );
        let ops = &code.programs[0].ops;
        let cases: Vec<_> = ops
            .iter()
            .filter_map(|o| match o {
                Op::CaseJump { ranges, .. } => Some(ranges.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(cases.len(), 2);
        assert_eq!(*cases[0], vec![(0, 4)]);
        assert_eq!(*cases[1], vec![(7, 7)]);
    }
}
