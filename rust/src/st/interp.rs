//! Tree-walking interpreter for the lowered IR.
//!
//! Models a non-optimizing vendor ST runtime (the paper's §5.4 finding:
//! "the ICS code compilation process prioritizes predictability over
//! performance") while metering abstract instruction costs for the PLC
//! timing model.
//!
//! Memory model: globals + an FB-instance arena (all statically
//! allocated at load, IEC-style). `VAR_INPUT` aggregate arguments are
//! deep-copied (bytes metered); `VAR_IN_OUT` and POINTER values alias.

use std::ops::{Deref, DerefMut};
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;

use super::builtins;
use super::host::Host;
use super::ir::*;
use super::value::Value;

pub use super::host::FbInstance;

/// Runtime failure with source-line context.
#[derive(Debug, Clone)]
pub struct RuntimeError {
    pub line: u32,
    pub message: String,
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "runtime error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for RuntimeError {}

pub(crate) fn rerr(line: u32, msg: impl Into<String>) -> RuntimeError {
    RuntimeError { line, message: msg.into() }
}

/// Control-flow signal from statement execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Flow {
    Normal,
    Exit,
    Continue,
    Return,
}

/// Execution context for one frame.
struct Cx {
    frame: Vec<Value>,
    self_idx: Option<usize>,
}

/// The ST virtual machine (tree-walking tier).
///
/// Load-time state and the by-name host API live in the embedded
/// [`Host`] (shared with the bytecode [`super::Vm`] so the two tiers
/// cannot drift); `Interp` itself adds only the execution engine and
/// its frame pool. `Deref` keeps the familiar `interp.globals` /
/// `interp.meter` / `interp.instance_field(…)` surface intact.
pub struct Interp {
    pub host: Host,
    /// Frame pool: recycled `Vec<Value>` allocations for POU calls
    /// (the interpreter's hottest allocation site — see
    /// EXPERIMENTS.md §Perf).
    frame_pool: Vec<Vec<Value>>,
}

impl Deref for Interp {
    type Target = Host;
    fn deref(&self) -> &Host {
        &self.host
    }
}

impl DerefMut for Interp {
    fn deref_mut(&mut self) -> &mut Host {
        &mut self.host
    }
}

impl Interp {
    /// Instantiate a compiled unit: allocate globals, program instances,
    /// and every FB instance they declare.
    pub fn new(unit: Unit) -> Self {
        Interp {
            host: Host::new(Arc::new(unit)),
            frame_pool: Vec::new(),
        }
    }

    /// Set the BINARR/ARRBIN base directory.
    pub fn with_io_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.host.io_dir = dir.into();
        self
    }

    /// Surrender the load-time state (used by [`super::Vm::from_interp`]
    /// to adopt it wholesale).
    pub fn into_host(self) -> Host {
        self.host
    }

    /// Run a PROGRAM body once (one "scan" of that task).
    pub fn run_program(&mut self, name: &str) -> Result<(), RuntimeError> {
        let pid = self
            .unit
            .find_program(name)
            .ok_or_else(|| rerr(0, format!("no program {name}")))?;
        let inst = self.program_instances[pid];
        let fd = self.unit.clone();
        let fd = &fd.programs[pid].body;
        self.run_func(fd, Vec::new(), Some(inst))?;
        Ok(())
    }

    /// Call a FUNCTION by name with host-supplied arguments.
    pub fn call_function(
        &mut self,
        name: &str,
        args: Vec<Value>,
    ) -> Result<Value, RuntimeError> {
        let fid = self
            .unit
            .find_function(name)
            .ok_or_else(|| rerr(0, format!("no function {name}")))?;
        let unit = self.unit.clone();
        let fd = &unit.funcs[fid];
        self.run_func(fd, args, None)
    }

    /// Call a method on an arena instance by name.
    pub fn call_method(
        &mut self,
        inst: usize,
        method: &str,
        args: Vec<Value>,
    ) -> Result<Value, RuntimeError> {
        let fb_id = self.instances[inst].fb_id;
        let unit = self.unit.clone();
        let fb = &unit.fbs[fb_id];
        let midx = fb
            .methods
            .iter()
            .position(|m| m.name.eq_ignore_ascii_case(method))
            .ok_or_else(|| rerr(0, format!("no method {method}")))?;
        self.run_func(&fb.methods[midx], args, Some(inst))
    }

    // ------------------------------------------------------ execution
    /// Build a frame and run a POU body. `args` bind input (+inout)
    /// slots; inputs are deep-copied per IEC call-by-value (metered).
    fn run_func(
        &mut self,
        fd: &FuncDef,
        args: Vec<Value>,
        self_idx: Option<usize>,
    ) -> Result<Value, RuntimeError> {
        self.meter.calls += 1;
        if args.len() != fd.n_inputs + fd.n_inouts {
            return Err(rerr(
                0,
                format!(
                    "{}: expected {} args, got {}",
                    fd.name,
                    fd.n_inputs + fd.n_inouts,
                    args.len()
                ),
            ));
        }
        let mut frame: Vec<Value> =
            self.frame_pool.pop().unwrap_or_default();
        frame.clear();
        frame.reserve(fd.slots.len());
        frame.push(fd.slots[0].init.to_value()); // return slot
        for (i, a) in args.into_iter().enumerate() {
            if i < fd.n_inputs && a.is_aggregate() {
                // call-by-value: aggregates copied, bytes metered
                self.meter.copy_bytes += a.byte_size();
                frame.push(a.deep_clone());
            } else {
                // scalar input, or VAR_IN_OUT sharing the handle
                frame.push(a);
            }
        }
        for slot in fd.slots.iter().skip(frame.len()) {
            frame.push(slot.init.to_value());
        }
        let mut cx = Cx { frame, self_idx };
        let flow = self.exec_block(&fd.body, &mut cx);
        let ret = cx.frame.swap_remove(0);
        cx.frame.clear();
        self.frame_pool.push(cx.frame);
        flow?;
        Ok(ret)
    }

    fn exec_block(&mut self, body: &[St], cx: &mut Cx) -> Result<Flow, RuntimeError> {
        for st in body {
            match self.exec_stmt(st, cx)? {
                Flow::Normal => {}
                f => return Ok(f),
            }
        }
        Ok(Flow::Normal)
    }

    fn exec_stmt(&mut self, st: &St, cx: &mut Cx) -> Result<Flow, RuntimeError> {
        match st {
            St::Assign(lv, e, copy) => {
                let v = self.eval(e, cx)?;
                self.assign(lv, v, *copy, cx)?;
                Ok(Flow::Normal)
            }
            St::If(arms, else_body) => {
                self.meter.branches += 1;
                for (cond, body) in arms {
                    if self.eval(cond, cx)?.bool() {
                        return self.exec_block(body, cx);
                    }
                }
                self.exec_block(else_body, cx)
            }
            St::Case(scrut, arms, else_body) => {
                self.meter.branches += 1;
                let v = self.eval(scrut, cx)?.int();
                for (ranges, body) in arms {
                    if ranges.iter().any(|(lo, hi)| v >= *lo && v <= *hi) {
                        return self.exec_block(body, cx);
                    }
                }
                self.exec_block(else_body, cx)
            }
            St::For { var, from, to, by, body } => {
                let from = self.eval(from, cx)?.int();
                let to = self.eval(to, cx)?.int();
                let step = match by {
                    Some(b) => self.eval(b, cx)?.int(),
                    None => 1,
                };
                if step == 0 {
                    return Err(rerr(0, "FOR step of 0"));
                }
                let mut i = from;
                loop {
                    if (step > 0 && i > to) || (step < 0 && i < to) {
                        break;
                    }
                    self.meter.branches += 1;
                    self.assign(var, Value::Int(i), false, cx)?;
                    match self.exec_block(body, cx)? {
                        Flow::Exit => break,
                        Flow::Return => return Ok(Flow::Return),
                        _ => {}
                    }
                    self.meter.int_ops += 1;
                    // Wrapping, like every other IEC integer op (and the
                    // bytecode VM's ForIncr — the tiers must agree even
                    // at i64 extremes, where a debug-build `+=` would
                    // abort here while the VM wrapped).
                    i = i.wrapping_add(step);
                }
                Ok(Flow::Normal)
            }
            St::While(cond, body) => {
                loop {
                    self.meter.branches += 1;
                    if !self.eval(cond, cx)?.bool() {
                        break;
                    }
                    match self.exec_block(body, cx)? {
                        Flow::Exit => break,
                        Flow::Return => return Ok(Flow::Return),
                        _ => {}
                    }
                }
                Ok(Flow::Normal)
            }
            St::Repeat(body, until) => {
                loop {
                    match self.exec_block(body, cx)? {
                        Flow::Exit => break,
                        Flow::Return => return Ok(Flow::Return),
                        _ => {}
                    }
                    self.meter.branches += 1;
                    if self.eval(until, cx)?.bool() {
                        break;
                    }
                }
                Ok(Flow::Normal)
            }
            St::Exit => Ok(Flow::Exit),
            St::Continue => Ok(Flow::Continue),
            St::Return => Ok(Flow::Return),
            St::Expr(e) => {
                self.eval(e, cx)?;
                Ok(Flow::Normal)
            }
            St::FbInvoke { fb, fb_id, inputs, outputs, line } => {
                let inst = match self.eval(fb, cx)? {
                    Value::FbRef(h) => h,
                    _ => return Err(rerr(*line, "FB instance not bound")),
                };
                for (fidx, e, copy) in inputs {
                    let v = self.eval(e, cx)?;
                    self.store_field(inst, *fidx as usize, v, *copy)?;
                }
                let unit = self.unit.clone();
                let body = unit.fbs[*fb_id]
                    .body
                    .as_ref()
                    .ok_or_else(|| rerr(*line, "FB has no body"))?;
                self.run_func(body, Vec::new(), Some(inst))?;
                for (fidx, lv) in outputs {
                    let v = self.instances[inst].fields[*fidx as usize].clone();
                    let copy = v.is_aggregate();
                    self.assign(lv, v, copy, cx)?;
                }
                Ok(Flow::Normal)
            }
        }
    }

    fn store_field(
        &mut self,
        inst: usize,
        fidx: usize,
        v: Value,
        copy: bool,
    ) -> Result<(), RuntimeError> {
        self.meter.stores += 1;
        if copy {
            self.meter.copy_bytes += v.byte_size();
            let dst = self.instances[inst].fields[fidx].clone();
            copy_into(&v, &dst)?;
        } else {
            self.instances[inst].fields[fidx] = v;
        }
        Ok(())
    }

    // ------------------------------------------------------ assignment
    fn assign(
        &mut self,
        lv: &Lv,
        v: Value,
        copy: bool,
        cx: &mut Cx,
    ) -> Result<(), RuntimeError> {
        self.meter.stores += 1;
        match lv {
            Lv::Local(s) => {
                if copy {
                    self.meter.copy_bytes += v.byte_size();
                    let dst = cx.frame[*s as usize].clone();
                    copy_into(&v, &dst)?;
                } else {
                    cx.frame[*s as usize] = v;
                }
                Ok(())
            }
            Lv::Global(g) => {
                if copy {
                    self.meter.copy_bytes += v.byte_size();
                    let dst = self.globals[*g as usize].clone();
                    copy_into(&v, &dst)?;
                } else {
                    self.globals[*g as usize] = v;
                }
                Ok(())
            }
            Lv::SelfField(f) => {
                let inst = cx
                    .self_idx
                    .ok_or_else(|| rerr(0, "no self in this context"))?;
                self.store_field(inst, *f as usize, v, copy)
            }
            Lv::Field(base, f) => {
                let b = self.eval(base, cx)?;
                match b {
                    Value::Struct(s) => {
                        if copy {
                            self.meter.copy_bytes += v.byte_size();
                            let dst = s.borrow()[*f as usize].clone();
                            copy_into(&v, &dst)?;
                        } else {
                            s.borrow_mut()[*f as usize] = v;
                        }
                        Ok(())
                    }
                    _ => Err(rerr(0, "field store on non-struct")),
                }
            }
            Lv::FbField(base, f) => {
                let b = self.eval(base, cx)?;
                match b {
                    Value::FbRef(h) => self.store_field(h, *f as usize, v, copy),
                    _ => Err(rerr(0, "FB instance not bound")),
                }
            }
            Lv::Idx(base, idx, len, kind, line) => {
                let b = self.eval(base, cx)?;
                let i = self.eval(idx, cx)?.int();
                if i < 0 || i as u32 >= *len {
                    return Err(rerr(
                        *line,
                        format!("array index {i} out of bounds (len {len})"),
                    ));
                }
                let i = i as usize;
                match (kind, &b, v) {
                    (ElemKind::F32, Value::ArrF32(a), Value::Real(x)) => {
                        a.borrow_mut()[i] = x;
                        Ok(())
                    }
                    (ElemKind::F64, Value::ArrF64(a), Value::LReal(x)) => {
                        a.borrow_mut()[i] = x;
                        Ok(())
                    }
                    (ElemKind::Int, Value::ArrInt(a), Value::Int(x)) => {
                        a.borrow_mut()[i] = x;
                        Ok(())
                    }
                    (ElemKind::Int, Value::ArrInt(a), Value::Bool(x)) => {
                        a.borrow_mut()[i] = x as i64;
                        Ok(())
                    }
                    (ElemKind::Ref, Value::ArrRef(a), x) => {
                        a.borrow_mut()[i] = x;
                        Ok(())
                    }
                    _ => Err(rerr(*line, "array element store type mismatch")),
                }
            }
            Lv::PtrAt(base, off, kind, line) => {
                let p = self.eval(base, cx)?;
                let extra = match off {
                    Some(o) => self.eval(o, cx)?.int(),
                    None => 0,
                };
                if extra < 0 {
                    return Err(rerr(*line, "negative pointer offset"));
                }
                match (kind, &p, v) {
                    (PtrKind::F32, Value::PtrF32(a, base_off), Value::Real(x)) => {
                        let i = base_off + extra as usize;
                        let mut arr = a.borrow_mut();
                        if i >= arr.len() {
                            return Err(rerr(*line, "pointer store out of bounds"));
                        }
                        arr[i] = x;
                        Ok(())
                    }
                    (PtrKind::F64, Value::PtrF64(a, base_off), Value::LReal(x)) => {
                        let i = base_off + extra as usize;
                        let mut arr = a.borrow_mut();
                        if i >= arr.len() {
                            return Err(rerr(*line, "pointer store out of bounds"));
                        }
                        arr[i] = x;
                        Ok(())
                    }
                    (PtrKind::Int, Value::PtrInt(a, base_off), Value::Int(x)) => {
                        let i = base_off + extra as usize;
                        let mut arr = a.borrow_mut();
                        if i >= arr.len() {
                            return Err(rerr(*line, "pointer store out of bounds"));
                        }
                        arr[i] = x;
                        Ok(())
                    }
                    (_, Value::Null, _) => {
                        Err(rerr(*line, "null pointer store"))
                    }
                    _ => Err(rerr(*line, "pointer store type mismatch")),
                }
            }
        }
    }

    // ------------------------------------------------------ evaluation
    fn eval(&mut self, e: &Ex, cx: &mut Cx) -> Result<Value, RuntimeError> {
        Ok(match e {
            Ex::KBool(b) => Value::Bool(*b),
            Ex::KInt(v) => Value::Int(*v),
            Ex::KReal(v) => Value::Real(*v),
            Ex::KLReal(v) => Value::LReal(*v),
            Ex::KStr(s) => Value::Str(s.clone()),
            Ex::KNull => Value::Null,
            Ex::Local(s) => {
                self.meter.loads += 1;
                cx.frame[*s as usize].clone()
            }
            Ex::Global(g) => {
                self.meter.loads += 1;
                self.globals[*g as usize].clone()
            }
            Ex::SelfField(f) => {
                self.meter.loads += 1;
                let inst = cx
                    .self_idx
                    .ok_or_else(|| rerr(0, "no self in this context"))?;
                self.instances[inst].fields[*f as usize].clone()
            }
            Ex::Field(base, f) => {
                self.meter.loads += 1;
                match self.eval(base, cx)? {
                    Value::Struct(s) => s.borrow()[*f as usize].clone(),
                    _ => return Err(rerr(0, "field read on non-struct")),
                }
            }
            Ex::FbField(base, f) => {
                self.meter.loads += 1;
                match self.eval(base, cx)? {
                    Value::FbRef(h) => {
                        self.instances[h].fields[*f as usize].clone()
                    }
                    _ => return Err(rerr(0, "FB instance not bound")),
                }
            }
            Ex::Idx(base, idx, len, kind, line) => {
                let b = self.eval(base, cx)?;
                let i = self.eval(idx, cx)?.int();
                self.meter.loads += 1;
                if i < 0 || i as u32 >= *len {
                    return Err(rerr(
                        *line,
                        format!("array index {i} out of bounds (len {len})"),
                    ));
                }
                let i = i as usize;
                match (kind, &b) {
                    (ElemKind::F32, Value::ArrF32(a)) => {
                        Value::Real(a.borrow()[i])
                    }
                    (ElemKind::F64, Value::ArrF64(a)) => {
                        Value::LReal(a.borrow()[i])
                    }
                    (ElemKind::Int, Value::ArrInt(a)) => {
                        Value::Int(a.borrow()[i])
                    }
                    (ElemKind::Ref, Value::ArrRef(a)) => a.borrow()[i].clone(),
                    _ => return Err(rerr(*line, "array read type mismatch")),
                }
            }
            Ex::PtrLoad(base, off, kind, line) => {
                let p = self.eval(base, cx)?;
                let extra = match off {
                    Some(o) => self.eval(o, cx)?.int(),
                    None => 0,
                };
                self.meter.loads += 1;
                if extra < 0 {
                    return Err(rerr(*line, "negative pointer offset"));
                }
                match (kind, &p) {
                    (PtrKind::F32, Value::PtrF32(a, base_off)) => {
                        let arr = a.borrow();
                        let i = base_off + extra as usize;
                        if i >= arr.len() {
                            return Err(rerr(*line, "pointer read out of bounds"));
                        }
                        Value::Real(arr[i])
                    }
                    (PtrKind::F64, Value::PtrF64(a, base_off)) => {
                        let arr = a.borrow();
                        let i = base_off + extra as usize;
                        if i >= arr.len() {
                            return Err(rerr(*line, "pointer read out of bounds"));
                        }
                        Value::LReal(arr[i])
                    }
                    (PtrKind::Int, Value::PtrInt(a, base_off)) => {
                        let arr = a.borrow();
                        let i = base_off + extra as usize;
                        if i >= arr.len() {
                            return Err(rerr(*line, "pointer read out of bounds"));
                        }
                        Value::Int(arr[i])
                    }
                    (_, Value::Null) => {
                        return Err(rerr(*line, "null pointer read"))
                    }
                    _ => return Err(rerr(*line, "pointer read type mismatch")),
                }
            }
            Ex::Adr(lv, kind) => {
                self.meter.int_ops += 1;
                self.adr(lv, *kind, cx)?
            }
            Ex::NegF32(x) => {
                self.meter.fp_add += 1;
                Value::Real(-self.eval(x, cx)?.real())
            }
            Ex::NegF64(x) => {
                self.meter.fp_add += 1;
                Value::LReal(-self.eval(x, cx)?.lreal())
            }
            Ex::NegInt(x) => {
                self.meter.int_ops += 1;
                Value::Int(-self.eval(x, cx)?.int())
            }
            Ex::Not(x) => {
                self.meter.int_ops += 1;
                Value::Bool(!self.eval(x, cx)?.bool())
            }
            Ex::Arith(op, kind, a, b, line) => self.arith(*op, *kind, a, b, *line, cx)?,
            Ex::Cmp(op, kind, a, b) => {
                match kind {
                    NumKind::Int => self.meter.cmp += 1,
                    _ => self.meter.fp_cmp += 1,
                }
                let av = self.eval(a, cx)?;
                let bv = self.eval(b, cx)?;
                let r = match kind {
                    NumKind::F32 => cmp_ord(*op, av.real().partial_cmp(&bv.real())),
                    NumKind::F64 => {
                        cmp_ord(*op, av.lreal().partial_cmp(&bv.lreal()))
                    }
                    NumKind::Int => cmp_ord(*op, Some(av.int().cmp(&bv.int()))),
                };
                Value::Bool(r)
            }
            Ex::CmpBool(op, a, b) => {
                self.meter.cmp += 1;
                let av = self.eval(a, cx)?.bool();
                let bv = self.eval(b, cx)?.bool();
                Value::Bool(match op {
                    CmpOp::Eq => av == bv,
                    CmpOp::Neq => av != bv,
                    _ => return Err(rerr(0, "ordering on BOOL")),
                })
            }
            Ex::BoolB(op, a, b) => {
                self.meter.int_ops += 1;
                let av = self.eval(a, cx)?.bool();
                let bv = self.eval(b, cx)?.bool();
                Value::Bool(match op {
                    BoolOp::And => av && bv,
                    BoolOp::Or => av || bv,
                    BoolOp::Xor => av ^ bv,
                })
            }
            Ex::IntB(op, a, b) => {
                self.meter.int_ops += 1;
                let av = self.eval(a, cx)?.int();
                let bv = self.eval(b, cx)?.int();
                Value::Int(match op {
                    BoolOp::And => av & bv,
                    BoolOp::Or => av | bv,
                    BoolOp::Xor => av ^ bv,
                })
            }
            Ex::IntToF32(x) => {
                self.meter.converts += 1;
                Value::Real(self.eval(x, cx)?.int() as f32)
            }
            Ex::IntToF64(x) => {
                self.meter.converts += 1;
                Value::LReal(self.eval(x, cx)?.int() as f64)
            }
            Ex::F32ToF64(x) => {
                self.meter.converts += 1;
                Value::LReal(self.eval(x, cx)?.real() as f64)
            }
            Ex::F64ToF32(x) => {
                self.meter.converts += 1;
                Value::Real(self.eval(x, cx)?.lreal() as f32)
            }
            Ex::F32ToInt(x, it) => {
                self.meter.converts += 1;
                Value::Int(builtins::real_to_int(self.eval(x, cx)?.real() as f64, *it))
            }
            Ex::F64ToInt(x, it) => {
                self.meter.converts += 1;
                Value::Int(builtins::real_to_int(self.eval(x, cx)?.lreal(), *it))
            }
            Ex::IntNarrow(x, it) => {
                self.meter.converts += 1;
                Value::Int(it.wrap(self.eval(x, cx)?.int()))
            }
            Ex::BoolToInt(x) => {
                self.meter.converts += 1;
                Value::Int(self.eval(x, cx)?.bool() as i64)
            }
            Ex::CallFn(fid, args) => {
                let mut vals = Vec::with_capacity(args.len());
                for a in args {
                    vals.push(self.eval(a, cx)?);
                }
                let unit = self.unit.clone();
                self.run_func(&unit.funcs[*fid], vals, None)?
            }
            Ex::CallMethod(fb_id, midx, self_e, args) => {
                let inst = match self.eval(self_e, cx)? {
                    Value::FbRef(h) => h,
                    _ => return Err(rerr(0, "FB instance not bound")),
                };
                let mut vals = Vec::with_capacity(args.len());
                for a in args {
                    vals.push(self.eval(a, cx)?);
                }
                let unit = self.unit.clone();
                self.run_func(&unit.fbs[*fb_id].methods[*midx], vals, Some(inst))?
            }
            Ex::CallIface(iid, mid, self_e, args, line) => {
                let inst = match self.eval(self_e, cx)? {
                    Value::FbRef(h) => h,
                    Value::Null => {
                        return Err(rerr(*line, "interface variable is not bound"))
                    }
                    _ => return Err(rerr(*line, "bad interface value")),
                };
                let fb_id = self.instances[inst].fb_id;
                let unit = self.unit.clone();
                let table = unit.fbs[fb_id].vtables[*iid]
                    .as_ref()
                    .ok_or_else(|| {
                        rerr(
                            *line,
                            format!(
                                "{} does not implement {}",
                                unit.fbs[fb_id].name, unit.ifaces[*iid].name
                            ),
                        )
                    })?;
                let midx = table[*mid];
                let mut vals = Vec::with_capacity(args.len());
                for a in args {
                    vals.push(self.eval(a, cx)?);
                }
                self.run_func(&unit.fbs[fb_id].methods[midx], vals, Some(inst))?
            }
            Ex::Intrinsic(b, kind, args, line) => {
                self.intrinsic(*b, *kind, args, *line, cx)?
            }
            Ex::StructLit(sid, fields) => {
                let unit = self.unit.clone();
                let mut vals: Vec<Value> = unit.structs[*sid]
                    .fields
                    .iter()
                    .map(|f| f.init.to_value())
                    .collect();
                for (idx, e) in fields {
                    vals[*idx as usize] = self.eval(e, cx)?;
                    self.meter.stores += 1;
                }
                Value::Struct(std::rc::Rc::new(std::cell::RefCell::new(vals)))
            }
        })
    }

    fn adr(&mut self, lv: &Lv, kind: PtrKind, cx: &mut Cx) -> Result<Value, RuntimeError> {
        // Resolve the lvalue's backing storage; offset = element index
        // when applied to an array element.
        let (base_val, offset) = match lv {
            Lv::Local(s) => (cx.frame[*s as usize].clone(), 0usize),
            Lv::Global(g) => (self.globals[*g as usize].clone(), 0),
            Lv::SelfField(f) => {
                let inst = cx
                    .self_idx
                    .ok_or_else(|| rerr(0, "no self in this context"))?;
                (self.instances[inst].fields[*f as usize].clone(), 0)
            }
            Lv::Field(base, f) => match self.eval(base, cx)? {
                Value::Struct(s) => (s.borrow()[*f as usize].clone(), 0),
                _ => return Err(rerr(0, "ADR through non-struct")),
            },
            Lv::FbField(base, f) => match self.eval(base, cx)? {
                Value::FbRef(h) => {
                    (self.instances[h].fields[*f as usize].clone(), 0)
                }
                _ => return Err(rerr(0, "FB instance not bound")),
            },
            Lv::Idx(base, idx, len, _, line) => {
                let b = self.eval(base, cx)?;
                let i = self.eval(idx, cx)?.int();
                if i < 0 || i as u32 >= *len {
                    return Err(rerr(*line, "ADR index out of bounds"));
                }
                (b, i as usize)
            }
            Lv::PtrAt(base, off, _, line) => {
                // ADR(p[i]) — pointer arithmetic.
                let p = self.eval(base, cx)?;
                let extra = match off {
                    Some(o) => self.eval(o, cx)?.int(),
                    None => 0,
                };
                if extra < 0 {
                    return Err(rerr(*line, "negative pointer offset"));
                }
                return Ok(match (kind, p) {
                    (PtrKind::F32, Value::PtrF32(a, o)) => {
                        Value::PtrF32(a, o + extra as usize)
                    }
                    (PtrKind::F64, Value::PtrF64(a, o)) => {
                        Value::PtrF64(a, o + extra as usize)
                    }
                    (PtrKind::Int, Value::PtrInt(a, o)) => {
                        Value::PtrInt(a, o + extra as usize)
                    }
                    (_, Value::Null) => {
                        return Err(rerr(*line, "ADR through null pointer"))
                    }
                    _ => return Err(rerr(*line, "ADR pointer kind mismatch")),
                });
            }
        };
        Ok(match (kind, base_val) {
            (PtrKind::F32, Value::ArrF32(a)) => Value::PtrF32(a, offset),
            (PtrKind::F64, Value::ArrF64(a)) => Value::PtrF64(a, offset),
            (PtrKind::Int, Value::ArrInt(a)) => Value::PtrInt(a, offset),
            (_, other) => {
                return Err(rerr(0, format!("ADR of unsupported value {other:?}")))
            }
        })
    }

    fn arith(
        &mut self,
        op: ArithOp,
        kind: NumKind,
        a: &Ex,
        b: &Ex,
        line: u32,
        cx: &mut Cx,
    ) -> Result<Value, RuntimeError> {
        let av = self.eval(a, cx)?;
        let bv = self.eval(b, cx)?;
        Ok(match kind {
            NumKind::F32 => {
                let (x, y) = (av.real(), bv.real());
                Value::Real(match op {
                    ArithOp::Add => {
                        self.meter.fp_add += 1;
                        x + y
                    }
                    ArithOp::Sub => {
                        self.meter.fp_add += 1;
                        x - y
                    }
                    ArithOp::Mul => {
                        self.meter.fp_mul += 1;
                        x * y
                    }
                    ArithOp::Div => {
                        self.meter.fp_div += 1;
                        x / y
                    }
                    ArithOp::Pow => {
                        self.meter.fp_trans += 1;
                        x.powf(y)
                    }
                    ArithOp::Mod => return Err(rerr(line, "MOD on REAL")),
                })
            }
            NumKind::F64 => {
                let (x, y) = (av.lreal(), bv.lreal());
                Value::LReal(match op {
                    ArithOp::Add => {
                        self.meter.fp_add += 1;
                        x + y
                    }
                    ArithOp::Sub => {
                        self.meter.fp_add += 1;
                        x - y
                    }
                    ArithOp::Mul => {
                        self.meter.fp_mul += 1;
                        x * y
                    }
                    ArithOp::Div => {
                        self.meter.fp_div += 1;
                        x / y
                    }
                    ArithOp::Pow => {
                        self.meter.fp_trans += 1;
                        x.powf(y)
                    }
                    ArithOp::Mod => return Err(rerr(line, "MOD on LREAL")),
                })
            }
            NumKind::Int => {
                self.meter.int_ops += 1;
                let (x, y) = (av.int(), bv.int());
                Value::Int(match op {
                    ArithOp::Add => x.wrapping_add(y),
                    ArithOp::Sub => x.wrapping_sub(y),
                    ArithOp::Mul => x.wrapping_mul(y),
                    ArithOp::Div => {
                        if y == 0 {
                            return Err(rerr(line, "integer division by zero"));
                        }
                        x.wrapping_div(y)
                    }
                    ArithOp::Mod => {
                        if y == 0 {
                            return Err(rerr(line, "MOD by zero"));
                        }
                        x.wrapping_rem(y)
                    }
                    ArithOp::Pow => {
                        self.meter.fp_trans += 1;
                        (x as f64).powf(y as f64) as i64
                    }
                })
            }
        })
    }

    fn intrinsic(
        &mut self,
        b: Builtin,
        kind: NumKind,
        args: &[Ex],
        line: u32,
        cx: &mut Cx,
    ) -> Result<Value, RuntimeError> {
        match b {
            Builtin::BinArr | Builtin::ArrBin => {
                return self.file_io(b, args, line, cx)
            }
            _ => {}
        }
        let mut vals = Vec::with_capacity(args.len());
        for a in args {
            vals.push(self.eval(a, cx)?);
        }
        Ok(builtins::eval_intrinsic(&mut self.meter, b, kind, &vals))
    }

    /// BINARR / ARRBIN: the framework's binary file I/O utilities.
    /// Signature: (file: STRING, bytes: ANY_INT, dst/src: POINTER,
    /// elem_bytes: const) — the last arg is synthesized by lowering.
    fn file_io(
        &mut self,
        b: Builtin,
        args: &[Ex],
        line: u32,
        cx: &mut Cx,
    ) -> Result<Value, RuntimeError> {
        let fname = match self.eval(&args[0], cx)? {
            Value::Str(s) => s,
            _ => return Err(rerr(line, "BINARR/ARRBIN: filename not a STRING")),
        };
        let bytes = self.eval(&args[1], cx)?.int();
        let ptr = self.eval(&args[2], cx)?;
        let elem_bytes = match args.get(3) {
            Some(e) => self.eval(e, cx)?.int() as usize,
            None => 4,
        };
        let host = &mut self.host;
        builtins::exec_file_io(
            &mut host.meter,
            &host.io_dir,
            b,
            fname.as_ref(),
            bytes,
            &ptr,
            elem_bytes,
            line,
        )
    }
}

pub(crate) fn cmp_ord(op: CmpOp, ord: Option<std::cmp::Ordering>) -> bool {
    use std::cmp::Ordering::*;
    match (op, ord) {
        (CmpOp::Eq, Some(Equal)) => true,
        (CmpOp::Neq, Some(Less)) | (CmpOp::Neq, Some(Greater)) => true,
        (CmpOp::Lt, Some(Less)) => true,
        (CmpOp::Gt, Some(Greater)) => true,
        (CmpOp::Le, Some(Less)) | (CmpOp::Le, Some(Equal)) => true,
        (CmpOp::Ge, Some(Greater)) | (CmpOp::Ge, Some(Equal)) => true,
        _ => false,
    }
}

/// Copy `src` into `dst`'s existing storage (ST value semantics: array
/// assignment fills the destination's fixed memory, keeping pointers to
/// it valid). No-op on self-assignment. Shared with the bytecode VM.
pub(crate) fn copy_into(src: &Value, dst: &Value) -> Result<(), RuntimeError> {
    match (src, dst) {
        (Value::ArrF32(s), Value::ArrF32(d)) => {
            if !Rc::ptr_eq(s, d) {
                d.borrow_mut().copy_from_slice(&s.borrow());
            }
            Ok(())
        }
        (Value::ArrF64(s), Value::ArrF64(d)) => {
            if !Rc::ptr_eq(s, d) {
                d.borrow_mut().copy_from_slice(&s.borrow());
            }
            Ok(())
        }
        (Value::ArrInt(s), Value::ArrInt(d)) => {
            if !Rc::ptr_eq(s, d) {
                d.borrow_mut().copy_from_slice(&s.borrow());
            }
            Ok(())
        }
        (Value::ArrRef(s), Value::ArrRef(d)) => {
            if !Rc::ptr_eq(s, d) {
                d.borrow_mut().clone_from_slice(&s.borrow());
            }
            Ok(())
        }
        (Value::Struct(s), Value::Struct(d)) => {
            if Rc::ptr_eq(s, d) {
                return Ok(());
            }
            let sb = s.borrow();
            let mut db = d.borrow_mut();
            for (sv, dv) in sb.iter().zip(db.iter_mut()) {
                match (sv, &*dv) {
                    (
                        Value::ArrF32(_) | Value::ArrF64(_) | Value::ArrInt(_)
                        | Value::ArrRef(_) | Value::Struct(_),
                        _,
                    ) => copy_into(sv, dv)?,
                    _ => *dv = sv.clone(),
                }
            }
            Ok(())
        }
        _ => Err(rerr(0, "aggregate copy type mismatch")),
    }
}
