//! # ICSML reproduction — native ML inference for IEC 61131-3 PLCs
//!
//! Rust + JAX + Pallas three-layer reproduction of *ICSML: Industrial
//! Control Systems ML Framework for native inference using IEC 61131-3
//! code* (Doumanidis, Rajput, Maniatakos — CPSS 2023).
//!
//! The crate hosts every substrate the paper depends on (see DESIGN.md):
//!
//! * [`api`] — **the inference contract**, two-level: [`api::Backend`]
//!   is the immutable, thread-shareable model handle; [`api::Session`]
//!   is per-request mutable state it mints (allocation-free
//!   `infer_into`, batch-first `infer_batch`, the [`api::PartialSession`]
//!   resumable sub-API for §6.3 multipart inference, typed
//!   [`api::InferenceError`], [`api::ModelSpec`] capability
//!   discovery). Every substrate below implements it; every consumer
//!   is written against it. See `API.md`.
//! * [`st`] — an IEC 61131-3 Structured Text front end with two
//!   execution tiers: the tree-walking [`st::Interp`] oracle and the
//!   register-bytecode [`st::Vm`] fast tier, both enforcing the
//!   standard's restrictions and metering identical instruction costs
//!   (the Codesys-runtime substitute the benchmarks run on).
//! * [`icsml_st`] — the ICSML framework itself, written in ST, embedded
//!   as assets and executed by [`st`].
//! * [`engine`] — a native-Rust ICSML engine with identical semantics
//!   (the paper's §5.4 "reimplemented in C++ -O3" comparator; served
//!   through [`api::EngineBackend`]).
//! * [`plc`] — scan-cycle PLC simulator: ADC models, Table-1 hardware
//!   profiles, timing + memory accounting.
//! * [`msf`] — MSF desalination plant + cascaded PID + attack injector
//!   (the Simulink HITL substitute).
//! * [`hitl`] / [`defense`] — the §7 case study: closed loop + on-PLC
//!   anomaly detector (a consumer of [`api::Backend`]).
//! * [`quant`] — §6.1 SINT/INT/DINT integer quantization.
//! * [`porting`] — §4.3 (+§8.2) model porting: manifest → ST codegen.
//! * [`runtime`] — PJRT executor for the AOT-lowered JAX/Pallas models
//!   (the TFLite-comparator path; served through
//!   [`runtime::XlaBackend`]).
//! * [`coordinator`] — shared backend router (per-caller routing
//!   sessions, policy fallback) + the §6.3 multipart scheduler, both
//!   generic over the [`api`] traits.
//! * [`serve`] — the concurrent serving layer: [`serve::Pool`] shards
//!   requests across worker threads with per-worker sessions over one
//!   shared backend, scheduled by priority class + earliest deadline
//!   ([`serve::DeadlineQueue`]), with deadline-compatible
//!   micro-batching, typed sheds and a cost-model
//!   [`serve::Admission`] gate (see `docs/ARCHITECTURE.md` for the
//!   whole-stack map).
//! * [`netserve`] — the network front door over [`serve`]: a
//!   length-prefixed binary wire protocol, a nonblocking poll-reactor
//!   TCP server completing requests from ticket readiness (no thread
//!   per in-flight request), a lazily-loading LRU
//!   [`netserve::ModelRegistry`] routing named models to per-model
//!   pools, and a blocking [`netserve::Client`].
//! * [`fleet`] — fleet-scale closed-loop simulation over the serving
//!   tier: a declarative attack-scenario corpus
//!   ([`fleet::ScenarioFamily`] taxonomy compiled onto
//!   [`msf::Attack`] primitives), a deterministic lock-step traffic
//!   generator multiplexing every plant's Control/Defense/Batch
//!   requests over pools or the network client with verdicts fed
//!   back as defense responses, and fleet SLO reports
//!   ([`fleet::FleetReport`]).

pub mod api;
pub mod coordinator;
pub mod defense;
pub mod engine;
pub mod fleet;
pub mod hitl;
pub mod icsml_st;
pub mod msf;
pub mod netserve;
pub mod plc;
pub mod porting;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod st;
pub mod util;

pub use api::{
    Backend, InferenceError, ModelSpec, PartialSession, RowPlan, Session,
    SharedBackend,
};

/// Returns the repository root (assumes `cargo run`/`cargo test` from the
/// workspace, or the `ICSML_ROOT` env var in deployed settings).
pub fn repo_root() -> std::path::PathBuf {
    if let Ok(root) = std::env::var("ICSML_ROOT") {
        return root.into();
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Default artifacts directory (`artifacts/`, falling back to the
/// fast-mode build `artifacts_fast/` when only that exists).
pub fn artifacts_dir() -> std::path::PathBuf {
    let root = repo_root();
    let full = root.join("artifacts");
    if full.join("manifest.json").exists() {
        return full;
    }
    let fast = root.join("artifacts_fast");
    if fast.join("manifest.json").exists() {
        return fast;
    }
    full
}
