//! Minimal property-testing harness (proptest substitute for the
//! offline build): seeded generators + counterexample shrinking for the
//! coordinator/engine invariant tests.
//!
//! Usage:
//! ```ignore
//! prop_check(100, |g| {
//!     let xs = g.vec_f64(1..=64, -10.0, 10.0);
//!     let s: f64 = xs.iter().sum();
//!     prop_assert(s.is_finite(), format!("sum not finite: {xs:?}"))
//! });
//! ```

use super::rng::SplitMix64;

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Generator handle passed to properties. Records the draws so failing
/// cases can be replayed at a smaller size.
pub struct Gen {
    rng: SplitMix64,
    /// Size hint in [0.0, 1.0]; shrinking retries with smaller sizes.
    pub size: f64,
}

impl Gen {
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        if hi == lo {
            return lo;
        }
        // Scale the upper bound with the current shrink size.
        let span = ((hi - lo) as f64 * self.size).ceil() as usize;
        lo + (self.rng.next_u64() as usize) % (span.max(1) + 1)
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64;
        lo + (self.rng.next_u64() % (span + 1)) as i64
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi * self.size + lo * (1.0 - self.size))
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64_in(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(
        &mut self,
        len: std::ops::RangeInclusive<usize>,
        lo: f32,
        hi: f32,
    ) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_f64(
        &mut self,
        len: std::ops::RangeInclusive<usize>,
        lo: f64,
        hi: f64,
    ) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.rng.next_u64() as usize) % xs.len()]
    }
}

/// Run `cases` random evaluations of `prop`. On failure, retries the same
/// seed at smaller generator sizes to report the smallest reproduction
/// found, then panics with the seed + message.
pub fn prop_check<F: FnMut(&mut Gen) -> PropResult>(cases: u64, mut prop: F) {
    let base_seed = std::env::var("ICSML_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E3779B9));
        let mut g = Gen { rng: SplitMix64::new(seed), size: 1.0 };
        if let Err(first_msg) = prop(&mut g) {
            // Shrink: replay the same seed with smaller size hints.
            let mut best = (1.0, first_msg);
            for &size in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                let mut g = Gen { rng: SplitMix64::new(seed), size };
                if let Err(msg) = prop(&mut g) {
                    best = (size, msg);
                }
            }
            panic!(
                "property failed (seed={seed}, case={case}, \
                 shrunk size={}): {}\nre-run with ICSML_PROP_SEED={base_seed}",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        prop_check(50, |g| {
            count += 1;
            let x = g.f64_in(0.0, 10.0);
            prop_assert(x >= 0.0, "negative")
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        prop_check(50, |g| {
            let x = g.f64_in(0.0, 10.0);
            prop_assert(x < 5.0, format!("x={x}"))
        });
    }

    #[test]
    fn generators_respect_ranges() {
        prop_check(200, |g| {
            let n = g.usize_in(3..=17);
            prop_assert(n >= 3 && n <= 17, format!("n={n}"))?;
            let v = g.vec_f32(1..=8, -2.0, 2.0);
            prop_assert(
                v.iter().all(|x| (-2.0..=2.0).contains(x)),
                format!("{v:?}"),
            )?;
            let i = g.i64_in(-5, 5);
            prop_assert((-5..=5).contains(&i), format!("i={i}"))
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut first: Vec<f64> = Vec::new();
        prop_check(5, |g| {
            first.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        let mut second: Vec<f64> = Vec::new();
        prop_check(5, |g| {
            second.push(g.f64_in(0.0, 1.0));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
