//! ICSML binary array I/O — the Rust twin of the framework's
//! `BINARR` / `ARRBIN` utility functions (paper §4.1: "load and save
//! array data from and to binary files", used for datasets, weights and
//! inference logs).
//!
//! Format: raw little-endian scalars, no header — exactly what
//! `numpy.ndarray.tofile` emits and what the ST `BINARR` built-in reads.

use std::fs::File;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

/// Read a raw little-endian `f32` array (BINARR semantics).
pub fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = read_bytes(path)?;
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "{}: length {} not a multiple of 4",
        path.display(),
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read a raw little-endian `i32` array.
pub fn read_i32(path: &Path) -> Result<Vec<i32>> {
    let bytes = read_bytes(path)?;
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "{}: length {} not a multiple of 4",
        path.display(),
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a raw little-endian `f32` array (ARRBIN semantics).
pub fn write_f32(path: &Path, data: &[f32]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut buf = Vec::with_capacity(data.len() * 4);
    for x in data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

fn read_bytes(path: &Path) -> Result<Vec<u8>> {
    let mut f =
        File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut bytes = Vec::new();
    f.read_to_end(&mut bytes)?;
    Ok(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_round_trip() {
        let dir = std::env::temp_dir().join("icsml_binio_test");
        let path = dir.join("arr.bin");
        let data = vec![1.5f32, -2.25, 0.0, f32::MAX, f32::MIN_POSITIVE];
        write_f32(&path, &data).unwrap();
        assert_eq!(read_f32(&path).unwrap(), data);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_misaligned_file() {
        let dir = std::env::temp_dir().join("icsml_binio_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [1u8, 2, 3]).unwrap();
        assert!(read_f32(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_file_is_error() {
        assert!(read_f32(Path::new("/nonexistent/x.bin")).is_err());
    }
}
