//! Micro-benchmark harness (criterion substitute for the offline build).
//!
//! Used by every `rust/benches/*.rs` target (`harness = false`): warmup,
//! adaptive iteration count, robust statistics, and the table printer
//! the paper-figure benches share.

use std::time::{Duration, Instant};

/// Summary statistics for one measured benchmark.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub std_ns: f64,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Benchmark runner with a time budget per measurement.
pub struct Bench {
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
    max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(400),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(10),
            budget: Duration::from_millis(100),
            min_iters: 3,
            max_iters: 2_000,
        }
    }

    /// Honors `ICSML_BENCH_FAST=1` (used by `cargo test` smoke runs).
    pub fn from_env() -> Self {
        if std::env::var("ICSML_BENCH_FAST").as_deref() == Ok("1") {
            Self::quick()
        } else {
            Self::default()
        }
    }

    /// Measure `f`, returning robust statistics. `f` should return some
    /// value dependent on its work to inhibit optimizing it away; pass it
    /// through [`std::hint::black_box`] inside the closure.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        // Warmup + pilot to size the measurement loop.
        let wstart = Instant::now();
        let mut pilot_iters = 0usize;
        while wstart.elapsed() < self.warmup || pilot_iters == 0 {
            f();
            pilot_iters += 1;
            if pilot_iters >= self.max_iters {
                break;
            }
        }
        let per_iter = wstart.elapsed().as_secs_f64() / pilot_iters as f64;
        let target = ((self.budget.as_secs_f64() / per_iter.max(1e-9)) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(target);
        for _ in 0..target {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        Stats {
            name: name.to_string(),
            iters: n,
            mean_ns: mean,
            median_ns: samples[n / 2],
            p10_ns: samples[n / 10],
            p90_ns: samples[(n * 9) / 10],
            std_ns: var.sqrt(),
        }
    }
}

/// Fixed-width table printer shared by the paper-figure benches.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:<w$} |", w = w));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}-|", "-".repeat(w + 2 - 1)));
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench::quick();
        let mut acc = 0u64;
        let s = b.run("noop", || {
            acc = std::hint::black_box(acc.wrapping_add(1));
        });
        assert!(s.mean_ns >= 0.0);
        assert!(s.iters >= 3);
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
    }

    #[test]
    fn ordering_of_workloads() {
        let b = Bench::quick();
        let fast = b.run("fast", || {
            std::hint::black_box((0..10u64).sum::<u64>());
        });
        let slow = b.run("slow", || {
            std::hint::black_box((0..100_000u64).sum::<u64>());
        });
        assert!(slow.median_ns > fast.median_ns);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.to_string();
        assert!(s.contains("| a | bb |"));
        assert!(s.lines().count() == 3);
    }
}
