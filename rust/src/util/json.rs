//! Minimal JSON parser / serializer (serde_json substitute).
//!
//! Handles the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) — enough for `artifacts/manifest.json`, the
//! golden plant trace, and benchmark report emission. Numbers are parsed
//! as `f64` (the only numeric type JSON actually has).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----------------------------------------------------------- access
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; panics with a readable path on miss.
    pub fn expect(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing JSON key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ------------------------------------------------------ construction
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ------------------------------------------------------------ parse
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -------------------------------------------------------- serialize
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse failure with byte offset context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, message: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number {text:?}")))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes at once.
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(v.expect("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            v.expect("a").as_arr().unwrap()[2].expect("b").as_str(),
            Some("x")
        );
    }

    #[test]
    fn round_trips() {
        let text = r#"{"arr":[1,2.5,true,null,"s\"q"],"n":-3}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".to_string())
        );
    }

    #[test]
    fn integer_formatting_is_compact() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
