//! Shared helpers for the paper-figure benchmarks (`rust/benches/*`):
//! ST benchmark-program generation (the paper's §5.2/§5.3 models),
//! per-phase metering, and temp-weight plumbing.

use std::path::PathBuf;

use crate::icsml_st;
use crate::porting::{codegen::CodegenOptions, generate_st_program, LayerSpec,
                     ModelSpec};
use crate::st::{Interp, Meter, Value};
use crate::util::{binio, json::Json, rng::SplitMix64};

/// Build a ModelSpec with random weights written to a temp dir.
/// Returns (spec, weights_dir).
pub fn random_spec(
    name: &str,
    sizes: &[usize],
    acts: &[&str],
    seed: u64,
) -> (ModelSpec, PathBuf) {
    let dir = std::env::temp_dir().join(format!("icsml_bench_{name}_{seed}"));
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = SplitMix64::new(seed);
    let mut layers = Vec::new();
    for i in 0..sizes.len() - 1 {
        let (n_in, n_out) = (sizes[i], sizes[i + 1]);
        let w: Vec<f32> = (0..n_in * n_out)
            .map(|_| rng.uniform(-0.5, 0.5) as f32)
            .collect();
        let b: Vec<f32> =
            (0..n_out).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
        binio::write_f32(&dir.join(format!("l{i}_w.bin")), &w).unwrap();
        binio::write_f32(&dir.join(format!("l{i}_b.bin")), &b).unwrap();
        layers.push(LayerSpec {
            inputs: n_in,
            neurons: n_out,
            weights: format!("l{i}_w.bin"),
            biases: format!("l{i}_b.bin"),
        });
    }
    let spec = ModelSpec {
        name: name.to_string(),
        sizes: sizes.to_vec(),
        activations: acts.iter().map(|s| s.to_string()).collect(),
        weights_dir: ".".into(),
        layers,
        report: Json::Null,
    };
    (spec, dir)
}

/// Load the generated ST program for a spec (fused or separate
/// activations) ready to run (weights dir attached, init scan done).
pub fn st_model(spec: &ModelSpec, dir: &PathBuf, fused: bool) -> Interp {
    let src = generate_st_program(
        spec,
        &CodegenOptions { program: "MAIN".into(), fused_activations: fused },
    );
    let mut it = icsml_st::load(&src)
        .unwrap_or_else(|e| panic!("bench ST failed to compile: {e}"));
    it.io_dir = dir.clone();
    it.run_program("MAIN").unwrap(); // init scan (BINARR + wiring)
    it
}

/// Run one inference scan and return the metered delta.
pub fn st_infer_meter(it: &mut Interp) -> Meter {
    let before = it.meter.clone();
    it.run_program("MAIN").unwrap();
    it.meter.since(&before)
}

/// Write an input vector into the generated program's `inputs` array.
pub fn st_set_inputs(it: &mut Interp, x: &[f32]) {
    let inst = it.program_instance("MAIN").unwrap();
    match it.instance_field(inst, "inputs").unwrap() {
        Value::ArrF32(a) => a.borrow_mut().copy_from_slice(x),
        other => panic!("inputs: {other:?}"),
    }
}

/// The paper's Fig. 4 stack sizes: `width` in/out, `depth` dense+ReLU.
pub fn stack_sizes(depth: usize, width: usize) -> Vec<usize> {
    let mut v = vec![width];
    v.extend(std::iter::repeat(width).take(depth));
    v
}

pub fn stack_acts(depth: usize) -> Vec<&'static str> {
    vec!["relu"; depth]
}
