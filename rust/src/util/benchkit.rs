//! Shared helpers for the paper-figure benchmarks (`rust/benches/*`):
//! ST benchmark-program generation (the paper's §5.2/§5.3 models),
//! per-phase metering, temp-weight plumbing, and machine-readable
//! result emission (`--json`).

use std::path::{Path, PathBuf};

use crate::icsml_st;
use crate::porting::{codegen::CodegenOptions, generate_st_program, LayerSpec,
                     ModelSpec};
use crate::st::{FusionConfig, Interp, Meter, Value, Vm};
use crate::util::{binio, json::Json, rng::SplitMix64};

/// Build a ModelSpec with random weights written to a temp dir.
/// Returns (spec, weights_dir).
pub fn random_spec(
    name: &str,
    sizes: &[usize],
    acts: &[&str],
    seed: u64,
) -> (ModelSpec, PathBuf) {
    let dir = std::env::temp_dir().join(format!("icsml_bench_{name}_{seed}"));
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = SplitMix64::new(seed);
    let mut layers = Vec::new();
    for i in 0..sizes.len() - 1 {
        let (n_in, n_out) = (sizes[i], sizes[i + 1]);
        let w: Vec<f32> = (0..n_in * n_out)
            .map(|_| rng.uniform(-0.5, 0.5) as f32)
            .collect();
        let b: Vec<f32> =
            (0..n_out).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
        binio::write_f32(&dir.join(format!("l{i}_w.bin")), &w).unwrap();
        binio::write_f32(&dir.join(format!("l{i}_b.bin")), &b).unwrap();
        layers.push(LayerSpec {
            inputs: n_in,
            neurons: n_out,
            weights: format!("l{i}_w.bin"),
            biases: format!("l{i}_b.bin"),
        });
    }
    let spec = ModelSpec {
        name: name.to_string(),
        sizes: sizes.to_vec(),
        activations: acts.iter().map(|s| s.to_string()).collect(),
        weights_dir: ".".into(),
        layers,
        report: Json::Null,
    };
    (spec, dir)
}

/// Load the generated ST program for a spec (fused or separate
/// activations) ready to run (weights dir attached, init scan done).
pub fn st_model(spec: &ModelSpec, dir: &PathBuf, fused: bool) -> Interp {
    let src = generate_st_program(
        spec,
        &CodegenOptions { program: "MAIN".into(), fused_activations: fused },
    );
    let mut it = icsml_st::load(&src)
        .unwrap_or_else(|e| panic!("bench ST failed to compile: {e}"));
    it.io_dir = dir.clone();
    it.run_program("MAIN").unwrap(); // init scan (BINARR + wiring)
    it
}

/// Load the generated ST program for a spec on the bytecode VM tier:
/// exactly [`st_model`]'s preparation (weights dir attached, init scan
/// done on the oracle), with the prepared state adopted wholesale —
/// one loader path, two tiers.
pub fn st_model_vm(spec: &ModelSpec, dir: &PathBuf, fused: bool) -> Vm {
    Vm::from_interp(st_model(spec, dir, fused))
}

/// [`st_model_vm`] with an explicit fusion configuration — lets the
/// benches time the plain (fusion-off) VM tier against the fused one
/// from the same prepared oracle state.
pub fn st_model_vm_with(
    spec: &ModelSpec,
    dir: &PathBuf,
    fused: bool,
    cfg: &FusionConfig,
) -> Vm {
    Vm::from_interp_with(st_model(spec, dir, fused), cfg)
}

/// Run one inference scan and return the metered delta.
pub fn st_infer_meter(it: &mut Interp) -> Meter {
    let before = it.meter.clone();
    it.run_program("MAIN").unwrap();
    it.meter.since(&before)
}

/// Run one VM inference scan and return the metered delta.
pub fn vm_infer_meter(vm: &mut Vm) -> Meter {
    let before = vm.meter.clone();
    vm.run_program("MAIN").unwrap();
    vm.meter.since(&before)
}

/// Write an input vector into the generated program's `inputs` array.
pub fn st_set_inputs(it: &mut Interp, x: &[f32]) {
    let inst = it.program_instance("MAIN").unwrap();
    match it.instance_field(inst, "inputs").unwrap() {
        Value::ArrF32(a) => a.borrow_mut().copy_from_slice(x),
        other => panic!("inputs: {other:?}"),
    }
}

/// Same for the VM tier.
pub fn vm_set_inputs(vm: &mut Vm, x: &[f32]) {
    let inst = vm.program_instance("MAIN").unwrap();
    match vm.instance_field(inst, "inputs").unwrap() {
        Value::ArrF32(a) => a.borrow_mut().copy_from_slice(x),
        other => panic!("inputs: {other:?}"),
    }
}

/// Read the generated program's `outputs` array.
pub fn vm_outputs(vm: &Vm) -> Vec<f32> {
    let inst = vm.program_instance("MAIN").unwrap();
    match vm.instance_field(inst, "outputs").unwrap() {
        Value::ArrF32(a) => a.borrow().clone(),
        other => panic!("outputs: {other:?}"),
    }
}

// ---------------------------------------------------------- JSON mode

/// One measured configuration for the machine-readable bench report.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Configuration label, e.g. `"interp/64x64x3"`.
    pub name: String,
    pub mean_ns: f64,
    pub median_ns: f64,
    /// Abstract ST ops executed per inference (`Meter::total_ops`).
    pub ops_per_inference: u64,
}

impl BenchRecord {
    /// Abstract ops retired per wall-clock nanosecond — the
    /// "ops/cycle"-style throughput figure for the executing tier.
    pub fn ops_per_ns(&self) -> f64 {
        if self.mean_ns > 0.0 {
            self.ops_per_inference as f64 / self.mean_ns
        } else {
            0.0
        }
    }
}

/// `--json[=PATH]` flag scan for `harness = false` bench mains.
/// Returns the output path when JSON emission was requested
/// (default `BENCH_<tag>.json` in the current directory).
pub fn json_flag(tag: &str) -> Option<PathBuf> {
    for a in std::env::args() {
        if a == "--json" {
            return Some(PathBuf::from(format!("BENCH_{tag}.json")));
        }
        if let Some(path) = a.strip_prefix("--json=") {
            return Some(PathBuf::from(path));
        }
    }
    None
}

/// `--smoke` flag scan: one-iteration correctness run for CI.
pub fn smoke_flag() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// Serialize bench records (plus free-form extras such as speedup
/// summaries) to a JSON report the repo can track over time.
pub fn write_bench_json(
    path: &Path,
    bench: &str,
    records: &[BenchRecord],
    extras: Vec<(&str, Json)>,
) -> std::io::Result<()> {
    let mut results = Vec::new();
    for r in records {
        results.push(Json::obj(vec![
            ("name", Json::Str(r.name.clone())),
            ("mean_ns", Json::Num(r.mean_ns)),
            ("median_ns", Json::Num(r.median_ns)),
            ("ops_per_inference", Json::Num(r.ops_per_inference as f64)),
            ("ops_per_ns", Json::Num(r.ops_per_ns())),
        ]));
    }
    let mut pairs = vec![
        ("bench", Json::Str(bench.to_string())),
        ("results", Json::Arr(results)),
    ];
    pairs.extend(extras);
    std::fs::write(path, Json::obj(pairs).to_string() + "\n")
}

/// The paper's Fig. 4 stack sizes: `width` in/out, `depth` dense+ReLU.
pub fn stack_sizes(depth: usize, width: usize) -> Vec<usize> {
    let mut v = vec![width];
    v.extend(std::iter::repeat(width).take(depth));
    v
}

pub fn stack_acts(depth: usize) -> Vec<&'static str> {
    vec!["relu"; depth]
}
