//! SplitMix64 PRNG — the *normative twin* of `python/compile/plant.py`'s
//! `SplitMix64`. The golden-trace cross-validation and the HITL noise
//! model depend on the two implementations producing bit-identical
//! streams; `test_splitmix64_reference_vector` pins both to the
//! published reference stream.

/// Deterministic 64-bit PRNG (Steele et al., "Fast splittable
/// pseudorandom number generators").
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision (matches the
    /// Python twin's `next_f64`).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) (n > 0). Simple modulo — bias is
    /// irrelevant at our n << 2^64 scales.
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Standard normal via Box-Muller (cosine branch only — identical to
    /// the Python twin by spec).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_splitmix64_reference_vector() {
        // Same vector as python/tests/test_plant.py (seed = 0).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(123);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(42);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }
}
