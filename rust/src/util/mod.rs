//! In-repo substitutes for crates unavailable in the offline build
//! environment (only the `xla` dependency closure is vendored), plus
//! small shared helpers.
//!
//! | module    | replaces          | used for                            |
//! |-----------|-------------------|-------------------------------------|
//! | [`json`]  | serde/serde_json  | manifest + golden-trace parsing     |
//! | [`rng`]   | rand              | deterministic noise / prop tests    |
//! | [`cli`]   | clap              | the `icsml` binary's subcommands    |
//! | [`bench`] | criterion         | `cargo bench` harnesses             |
//! | [`prop`]  | proptest          | property tests on invariants        |
//! | [`binio`] | —                 | ICSML BINARR/ARRBIN binary files    |
//! | [`lock`]  | —                 | poison-recovering Mutex/Condvar use |

pub mod bench;
pub mod benchkit;
pub mod binio;
pub mod cli;
#[doc(hidden)]
pub mod fixtures;
pub mod json;
pub mod lock;
pub mod prop;
pub mod rng;
