//! Tiny CLI argument parser (clap substitute for the offline build).
//!
//! Supports `binary <subcommand> [--flag value] [--switch] [positional…]`.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, `--key value` options, `--switch`
/// booleans, and positional arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit argv (excluding the program name).
    /// `known_switches` lists flags that take no value.
    pub fn parse_from(argv: &[String], known_switches: &[&str]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if known_switches.contains(&name) {
                    out.switches.push(name.to_string());
                } else if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() {
                    out.options.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    /// Parse the process arguments.
    pub fn parse(known_switches: &[&str]) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse_from(&argv, known_switches)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.opt(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} expects an integer")))
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.opt(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} expects a number")))
            .unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_switches() {
        let a = Args::parse_from(
            &argv(&["bench", "--profile", "wago", "--verbose", "extra"]),
            &["verbose"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.opt("profile"), Some("wago"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn parses_equals_form() {
        let a = Args::parse_from(&argv(&["run", "--steps=50"]), &[]);
        assert_eq!(a.opt_usize("steps", 0), 50);
    }

    #[test]
    fn defaults() {
        let a = Args::parse_from(&argv(&[]), &[]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.opt_or("x", "d"), "d");
        assert_eq!(a.opt_f64("y", 1.5), 1.5);
    }

    #[test]
    fn trailing_flag_without_value_is_switch() {
        let a = Args::parse_from(&argv(&["x", "--flag"]), &[]);
        assert!(a.has("flag"));
    }
}
