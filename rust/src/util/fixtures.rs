//! Shared model fixtures for the API contract tests (unit tests in
//! `coordinator::multipart` and the `tests/api_contract.rs`
//! integration suite use the same ported model, so the two cannot
//! drift apart).
//!
//! Not part of the public API — exported `#[doc(hidden)]` because
//! integration tests link the library without `cfg(test)`.

use crate::api::StBackend;
use crate::engine::{Act, Layer, Model};
use crate::porting::{
    codegen::CodegenOptions, generate_st_program, LayerSpec, ModelSpec,
};
use crate::util::{binio, json::Json, rng::SplitMix64};

/// Layer sizes of the fixture MLP (`RowPlan::from_layer_sizes` input).
pub const MLP_SIZES: [usize; 3] = [8, 16, 4];
const MLP_ACTS: [&str; 2] = ["relu", "linear"];

fn mlp_weights(seed: u64) -> Vec<(Vec<f32>, Vec<f32>)> {
    let mut rng = SplitMix64::new(seed);
    MLP_SIZES
        .windows(2)
        .map(|s| {
            let w: Vec<f32> = (0..s[0] * s[1])
                .map(|_| rng.uniform(-0.8, 0.8) as f32)
                .collect();
            let b: Vec<f32> =
                (0..s[1]).map(|_| rng.uniform(-0.2, 0.2) as f32).collect();
            (w, b)
        })
        .collect()
}

/// A seeded 8-16-4 MLP on the native engine.
pub fn mlp_8_16_4(seed: u64) -> Model {
    let layers = mlp_weights(seed)
        .into_iter()
        .enumerate()
        .map(|(i, (w, b))| {
            Layer::dense(w, b, MLP_SIZES[i], Act::from_name(MLP_ACTS[i]).unwrap())
        })
        .collect();
    Model::new(layers)
}

/// The same MLP ported to ICSML ST (weights written under a
/// `tag`-unique temp dir so parallel tests don't race) and loaded on
/// the interpreter, plus the identical engine model as reference.
pub fn ported_mlp_8_16_4(seed: u64, tag: &str) -> (StBackend, Model) {
    let dir = std::env::temp_dir().join(format!("icsml_fixture_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    let mut specs = Vec::new();
    for (i, (w, b)) in mlp_weights(seed).iter().enumerate() {
        binio::write_f32(&dir.join(format!("l{i}_w.bin")), w).unwrap();
        binio::write_f32(&dir.join(format!("l{i}_b.bin")), b).unwrap();
        specs.push(LayerSpec {
            inputs: MLP_SIZES[i],
            neurons: MLP_SIZES[i + 1],
            weights: format!("l{i}_w.bin"),
            biases: format!("l{i}_b.bin"),
        });
    }
    let spec = ModelSpec {
        name: "fixture".into(),
        sizes: MLP_SIZES.to_vec(),
        activations: MLP_ACTS.iter().map(|s| s.to_string()).collect(),
        weights_dir: ".".into(),
        layers: specs,
        report: Json::Null,
    };
    let src = generate_st_program(&spec, &CodegenOptions::default());
    let mut interp = crate::icsml_st::load(&src).unwrap();
    interp.io_dir = dir;
    let st = StBackend::new(interp, "MAIN")
        .expect("fixture program probes inputs/outputs");
    (st, mlp_8_16_4(seed))
}
