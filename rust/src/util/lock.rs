//! Poison-recovering `std::sync` lock helpers.
//!
//! A panicking lock holder poisons a `std` `Mutex`; every later
//! `lock().unwrap()` then panics too, cascading one contained fault
//! into unrelated requests. That is exactly the failure amplification
//! this serving stack exists to avoid: all shared state guarded by
//! these locks (registry slots, scheduler queues, worker bookkeeping)
//! is kept consistent by construction — guards are held only across
//! short, non-panicking critical sections — so recovering the guard
//! is always sound here. These helpers make the recovery explicit and
//! give the pattern one audited home instead of a scattering of
//! `unwrap_or_else(PoisonError::into_inner)` calls.
//!
//! Used across `serve/` and `netserve/` (the supervised-pool layer
//! deliberately contains backend panics with `catch_unwind`, which is
//! when poisoned locks would otherwise start cascading).

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the guard if a previous holder panicked.
///
/// ```
/// use std::sync::Mutex;
/// use icsml::util::lock::lock_recover;
///
/// let m = Mutex::new(7);
/// *lock_recover(&m) += 1;
/// assert_eq!(*lock_recover(&m), 8);
/// ```
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Block on `cv` with `guard`, recovering the reacquired guard if the
/// mutex was poisoned while this thread slept.
pub fn wait_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(41));
        let m2 = Arc::clone(&m);
        // Poison the mutex by panicking while holding the guard.
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "the mutex really is poisoned");
        // The helper still hands out a usable guard.
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 42);
    }
}
