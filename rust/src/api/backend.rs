//! The [`Backend`] trait — the crate's one inference contract.

use crate::st::Meter;

use super::error::InferenceError;
use super::partial::PartialBackend;
use super::spec::ModelSpec;

/// Validate single-request buffers against a spec — the one
/// single-shot shape contract, shared by every backend
/// implementation.
pub fn check_shapes(
    spec: &ModelSpec,
    x: &[f32],
    out: &[f32],
) -> Result<(), InferenceError> {
    if x.len() != spec.in_dim {
        return Err(InferenceError::ShapeMismatch {
            what: "input",
            expected: spec.in_dim,
            got: x.len(),
        });
    }
    if out.len() != spec.out_dim {
        return Err(InferenceError::ShapeMismatch {
            what: "output",
            expected: spec.out_dim,
            got: out.len(),
        });
    }
    Ok(())
}

/// Validate batch buffers against a spec and return the row count —
/// the one batch shape contract, shared by the trait default and
/// overriding backends (XLA).
pub fn check_batch_shapes(
    spec: &ModelSpec,
    xs: &[f32],
    out: &[f32],
) -> Result<usize, InferenceError> {
    if spec.in_dim == 0 || xs.len() % spec.in_dim != 0 {
        return Err(InferenceError::ShapeMismatch {
            what: "batch input",
            expected: spec.in_dim.max(1),
            got: xs.len(),
        });
    }
    let n = xs.len() / spec.in_dim;
    if out.len() != n * spec.out_dim {
        return Err(InferenceError::ShapeMismatch {
            what: "batch output",
            expected: n * spec.out_dim,
            got: out.len(),
        });
    }
    Ok(n)
}

/// An inference execution substrate.
///
/// The only method an implementor *must* provide beyond identity is
/// [`Backend::infer_into`] — the single-request, allocation-free hot
/// path. Everything else ([`Backend::infer`], [`Backend::infer_batch`])
/// has a correct default built on it; backends override the defaults
/// only when their substrate can do better (e.g. XLA executing a whole
/// batch in one call).
pub trait Backend {
    /// Stable identifier ("engine", "st", "xla", ...).
    fn name(&self) -> &'static str;

    /// Shape and capability descriptor for the loaded model.
    fn spec(&self) -> ModelSpec;

    /// Classifier logits for one feature vector, written into `out`.
    ///
    /// `x.len()` must equal `spec().in_dim` and `out.len()` must equal
    /// `spec().out_dim`; anything else is a
    /// [`InferenceError::ShapeMismatch`]. Implementations must not
    /// allocate on the hot path where the substrate allows it (the
    /// engine path is allocation-free; asserted in
    /// `tests/api_contract.rs`).
    fn infer_into(&mut self, x: &[f32], out: &mut [f32]) -> Result<(), InferenceError>;

    /// Allocating convenience wrapper around [`Backend::infer_into`].
    fn infer(&mut self, x: &[f32]) -> Result<Vec<f32>, InferenceError> {
        let mut out = vec![0.0f32; self.spec().out_dim];
        self.infer_into(x, &mut out)?;
        Ok(out)
    }

    /// Batched inference: `xs` holds `n` row-major feature vectors
    /// (`n * in_dim` values), `out` receives `n * out_dim` logits.
    /// Returns `n`.
    ///
    /// The default implementation loops [`Backend::infer_into`] and is
    /// exactly equivalent to `n` sequential calls (property-tested in
    /// `tests/api_contract.rs`); backends with a genuinely batched
    /// substrate override it.
    fn infer_batch(&mut self, xs: &[f32], out: &mut [f32]) -> Result<usize, InferenceError> {
        let spec = self.spec();
        let (in_dim, out_dim) = (spec.in_dim, spec.out_dim);
        let n = check_batch_shapes(&spec, xs, out)?;
        for i in 0..n {
            self.infer_into(
                &xs[i * in_dim..(i + 1) * in_dim],
                &mut out[i * out_dim..(i + 1) * out_dim],
            )?;
        }
        Ok(n)
    }

    /// Metered ST ops for the last inference (backends with
    /// `spec().supports_meter` only).
    fn last_meter(&self) -> Option<Meter> {
        None
    }

    /// Access the resumable §6.3 sub-API, when
    /// `spec().supports_partial`. Returns `None` on single-shot-only
    /// substrates; capable backends return `self`.
    fn partial(&mut self) -> Option<&mut dyn PartialBackend> {
        None
    }
}
