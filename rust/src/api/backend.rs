//! The [`Backend`] trait — the crate's immutable model-handle
//! contract.
//!
//! A backend owns everything *shared* about a loaded model — weights
//! behind `Arc` on the engine, the compiled `st::bytecode` program +
//! state image on the ST PLC, the PJRT executable on XLA — and answers
//! identity/capability queries over `&self`. All mutable per-request
//! state (scratch buffers, partial-inference cursors, meters) lives in
//! the [`Session`]s it mints: share the backend (`Arc<dyn Backend +
//! Send + Sync>`), give every caller/thread its own session.

use std::sync::Arc;

use super::error::InferenceError;
use super::session::Session;
use super::spec::ModelSpec;

/// Validate single-request buffers against a spec — the one
/// single-shot shape contract, shared by every session
/// implementation.
pub fn check_shapes(
    spec: &ModelSpec,
    x: &[f32],
    out: &[f32],
) -> Result<(), InferenceError> {
    if x.len() != spec.in_dim {
        return Err(InferenceError::ShapeMismatch {
            what: "input",
            expected: spec.in_dim,
            got: x.len(),
        });
    }
    if out.len() != spec.out_dim {
        return Err(InferenceError::ShapeMismatch {
            what: "output",
            expected: spec.out_dim,
            got: out.len(),
        });
    }
    Ok(())
}

/// Validate batch buffers against a spec and return the row count —
/// the one batch shape contract, shared by the session default and
/// overriding sessions (XLA).
pub fn check_batch_shapes(
    spec: &ModelSpec,
    xs: &[f32],
    out: &[f32],
) -> Result<usize, InferenceError> {
    if spec.in_dim == 0 || xs.len() % spec.in_dim != 0 {
        return Err(InferenceError::ShapeMismatch {
            what: "batch input",
            expected: spec.in_dim.max(1),
            got: xs.len(),
        });
    }
    let n = xs.len() / spec.in_dim;
    if out.len() != n * spec.out_dim {
        return Err(InferenceError::ShapeMismatch {
            what: "batch output",
            expected: n * spec.out_dim,
            got: out.len(),
        });
    }
    Ok(n)
}

/// An immutable handle to a loaded model on one execution substrate.
///
/// Identity and capabilities are `&self`; inference happens through
/// per-caller [`Session`]s ([`Backend::session`]). The in-crate
/// backends (engine, ST) are `Send + Sync` — one handle serves any
/// number of threads, each minting its own sessions — and a
/// [`SharedBackend`] is the currency the router and `serve::Pool`
/// deal in.
pub trait Backend {
    /// Stable identifier ("engine", "st", "xla", ...).
    fn name(&self) -> &'static str;

    /// Shape and capability descriptor for the loaded model.
    fn spec(&self) -> ModelSpec;

    /// Mint a fresh, independent inference session. Cheap relative to
    /// model loading; sessions own all mutable state, so sessions from
    /// one backend never observe each other.
    fn session(&self) -> Result<Box<dyn Session>, InferenceError>;
}

/// A thread-shareable backend handle — what multi-session consumers
/// (router, `serve::Pool`, the concurrency tests) pass around.
pub type SharedBackend = Arc<dyn Backend + Send + Sync>;
