//! Resumable inference: the §6.3 `begin`/`step`/`finish` sub-API.
//!
//! A multipart inference runs one logical request across many PLC scan
//! cycles. The session protocol:
//!
//! 1. [`PartialSession::begin`] latches the input and resets the row
//!    cursor;
//! 2. the scheduler calls [`PartialSession::step`] with a per-cycle
//!    row budget until [`PartialSession::finished`] — using
//!    [`PartialSession::next_row_macs`] to convert rows into modeled
//!    µs on a hardware profile;
//! 3. [`PartialSession::finish`] writes the logits and closes the
//!    session.
//!
//! Since the move to the Engine/Session split, the suspended state
//! lives inside one [`Session`] — many multipart inferences can be in
//! flight over one shared backend (one per session), where the old
//! design allowed one per *backend* and guarded it with `SessionState`
//! refusals. The coordinator's `MultipartSession` drives this over any
//! capable session.

use super::error::InferenceError;
use super::session::Session;

/// A session capable of resumable (multipart) inference.
///
/// At most one partial inference is active per session; `begin` while
/// one is in flight restarts it (matching the paper's semantics where
/// a new scan value preempts a stale inference).
pub trait PartialSession: Session {
    /// Start a resumable inference for input `x` (length
    /// `spec().in_dim`).
    fn begin(&mut self, x: &[f32]) -> Result<(), InferenceError>;

    /// A partial inference is active (begun and not yet
    /// finished+collected).
    fn in_flight(&self) -> bool;

    /// Rows left before the inference completes (0 once finished).
    fn remaining_rows(&self) -> usize;

    /// Modeled multiply-accumulate count of the next row — the
    /// scheduler's unit of cost. 0.0 when no row remains.
    fn next_row_macs(&self) -> f64;

    /// Advance by at most `row_budget` rows; returns rows actually
    /// consumed (≥ 1 while unfinished rows remain — a single row is
    /// the minimum schedulable unit).
    fn step(&mut self, row_budget: usize) -> Result<usize, InferenceError>;

    /// All rows have been consumed; `finish` may be called.
    fn finished(&self) -> bool;

    /// Write the inference's logits into `out` (length
    /// `spec().out_dim`) and close it.
    fn finish(&mut self, out: &mut [f32]) -> Result<(), InferenceError>;
}
