//! Resumable inference: the §6.3 `begin`/`step`/`finish` sub-API.
//!
//! A multipart inference runs one logical request across many PLC scan
//! cycles. The session protocol:
//!
//! 1. [`PartialBackend::begin`] latches the input and resets the row
//!    cursor;
//! 2. the scheduler calls [`PartialBackend::step`] with a per-cycle
//!    row budget until [`PartialBackend::finished`] — using
//!    [`PartialBackend::next_row_macs`] to convert rows into modeled
//!    µs on a hardware profile;
//! 3. [`PartialBackend::finish`] writes the logits and closes the
//!    session.
//!
//! The coordinator's `MultipartSession` drives this over *any* capable
//! backend; it no longer owns a concrete engine model.

use super::backend::Backend;
use super::error::InferenceError;

/// A backend capable of resumable (multipart) inference.
///
/// At most one session is active per backend; `begin` while a session
/// is in flight restarts it (matching the paper's semantics where a
/// new scan value preempts a stale inference).
pub trait PartialBackend: Backend {
    /// Start a session for input `x` (length `spec().in_dim`).
    fn begin(&mut self, x: &[f32]) -> Result<(), InferenceError>;

    /// A session is active (begun and not yet finished+collected).
    fn in_flight(&self) -> bool;

    /// Rows left before the session completes (0 once finished).
    fn remaining_rows(&self) -> usize;

    /// Modeled multiply-accumulate count of the next row — the
    /// scheduler's unit of cost. 0.0 when no row remains.
    fn next_row_macs(&self) -> f64;

    /// Advance by at most `row_budget` rows; returns rows actually
    /// consumed (≥ 1 while unfinished rows remain — a single row is
    /// the minimum schedulable unit).
    fn step(&mut self, row_budget: usize) -> Result<usize, InferenceError>;

    /// All rows have been consumed; `finish` may be called.
    fn finished(&self) -> bool;

    /// Write the session's logits into `out` (length
    /// `spec().out_dim`) and close the session.
    fn finish(&mut self, out: &mut [f32]) -> Result<(), InferenceError>;
}
