//! The [`Session`] trait — per-request mutable inference state.
//!
//! A [`super::Backend`] is an immutable model handle; a `Session` is
//! everything mutable about serving requests from it: scratch buffers,
//! latched partial-inference state, the last [`Meter`]. Sessions are
//! cheap to mint ([`super::Backend::session`]), owned by exactly one
//! caller, and deliberately **not** `Sync` — concurrency comes from
//! many sessions over one shared backend, not from locking inside a
//! session.

use crate::st::Meter;

use super::backend::check_batch_shapes;
use super::error::InferenceError;
use super::partial::PartialSession;
use super::spec::ModelSpec;

/// One caller's mutable inference state over a shared model.
///
/// The only method an implementor *must* provide beyond identity is
/// [`Session::infer_into`] — the single-request, allocation-free hot
/// path. Everything else ([`Session::infer`], [`Session::infer_batch`])
/// has a correct default built on it; sessions override the defaults
/// only when their substrate can do better (e.g. XLA executing a whole
/// batch in one call).
pub trait Session {
    /// Stable identifier of the backing substrate ("engine", "st",
    /// "xla", ...).
    fn name(&self) -> &'static str;

    /// Shape and capability descriptor for the loaded model.
    fn spec(&self) -> ModelSpec;

    /// Classifier logits for one feature vector, written into `out`.
    ///
    /// `x.len()` must equal `spec().in_dim` and `out.len()` must equal
    /// `spec().out_dim`; anything else is a
    /// [`InferenceError::ShapeMismatch`]. Implementations must not
    /// allocate on the hot path where the substrate allows it (the
    /// engine session is allocation-free; asserted in
    /// `tests/api_contract.rs`).
    fn infer_into(&mut self, x: &[f32], out: &mut [f32])
        -> Result<(), InferenceError>;

    /// Allocating convenience wrapper around [`Session::infer_into`].
    fn infer(&mut self, x: &[f32]) -> Result<Vec<f32>, InferenceError> {
        let mut out = vec![0.0f32; self.spec().out_dim];
        self.infer_into(x, &mut out)?;
        Ok(out)
    }

    /// Batched inference: `xs` holds `n` row-major feature vectors
    /// (`n * in_dim` values), `out` receives `n * out_dim` logits.
    /// Returns `n`.
    ///
    /// The default implementation loops [`Session::infer_into`] and is
    /// exactly equivalent to `n` sequential calls (property-tested in
    /// `tests/api_contract.rs`); sessions with a genuinely batched
    /// substrate override it.
    fn infer_batch(
        &mut self,
        xs: &[f32],
        out: &mut [f32],
    ) -> Result<usize, InferenceError> {
        let spec = self.spec();
        let (in_dim, out_dim) = (spec.in_dim, spec.out_dim);
        let n = check_batch_shapes(&spec, xs, out)?;
        for i in 0..n {
            self.infer_into(
                &xs[i * in_dim..(i + 1) * in_dim],
                &mut out[i * out_dim..(i + 1) * out_dim],
            )?;
        }
        Ok(n)
    }

    /// Metered ST ops for the last inference (sessions whose backend
    /// reports `spec().supports_meter` only).
    fn last_meter(&self) -> Option<Meter> {
        None
    }

    /// Access the resumable §6.3 sub-API, when
    /// `spec().supports_partial`. Returns `None` on single-shot-only
    /// substrates; capable sessions return `self`.
    fn partial(&mut self) -> Option<&mut dyn PartialSession> {
        None
    }
}
