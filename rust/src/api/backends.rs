//! The two in-crate execution substrates behind [`Backend`]: the
//! native engine and the ST PLC on its bytecode VM. (The XLA/PJRT
//! adapter lives in [`crate::runtime`] next to the PJRT types it
//! wraps.)
//!
//! Both follow the same shape: the backend is the immutable, `Send +
//! Sync` model handle (engine weights behind `Arc<Model>`; the ST
//! program as a shared compiled [`CodeUnit`] plus a
//! [`HostImage`] state snapshot), and every [`Backend::session`] call
//! mints an independent [`Session`] owning all mutable scratch.

use std::sync::Arc;

use crate::engine::{Activations, Cursor, Layer, Model};
use crate::st::bytecode::CodeUnit;
use crate::st::{Host, HostImage, Interp, Meter, Value, Vm};

use super::backend::{check_shapes, Backend};
use super::error::InferenceError;
use super::partial::PartialSession;
use super::session::Session;
use super::spec::{ModelSpec, RowPlan};

// ---------------------------------------------------------------------
// Native engine
// ---------------------------------------------------------------------

/// Native-engine backend (the §5.4 comparator): immutable weights
/// behind `Arc`, shared by every session and thread.
pub struct EngineBackend {
    model: Arc<Model>,
    spec: ModelSpec,
}

/// The engine capability descriptor for a model (shared between the
/// backend and its sessions).
fn engine_spec(model: &Model) -> ModelSpec {
    let quantization = model.layers().iter().find_map(|l| match l {
        Layer::QuantDense { scheme, .. } => Some(*scheme),
        _ => None,
    });
    ModelSpec {
        in_dim: model.in_dim(),
        out_dim: model.out_dim(),
        supports_partial: true,
        supports_meter: false,
        quantization,
        batch_granularity: 1,
    }
}

impl EngineBackend {
    /// Take ownership of a model and wrap it in a shareable handle.
    pub fn new(model: Model) -> EngineBackend {
        EngineBackend::shared(Arc::new(model))
    }

    /// Wrap an already-shared model (e.g. one `Arc<Model>` behind
    /// several differently-configured backends).
    pub fn shared(model: Arc<Model>) -> EngineBackend {
        let spec = engine_spec(&model);
        EngineBackend { model, spec }
    }

    /// The shared weights.
    pub fn model(&self) -> &Arc<Model> {
        &self.model
    }
}

impl Backend for EngineBackend {
    fn name(&self) -> &'static str {
        "engine"
    }

    fn spec(&self) -> ModelSpec {
        self.spec.clone()
    }

    fn session(&self) -> Result<Box<dyn Session>, InferenceError> {
        Ok(Box::new(EngineSession::new(Arc::clone(&self.model))))
    }
}

/// One caller's engine session: pre-sized activation buffers over the
/// shared model. Fully resumable: the engine evaluates in (layer, row)
/// chunks, so the partial sub-API maps 1:1 onto
/// [`Model::infer_partial_with`], and the suspended state lives
/// entirely in this session's [`Activations`].
pub struct EngineSession {
    model: Arc<Model>,
    spec: ModelSpec,
    acts: Activations,
    input: Vec<f32>,
    out_buf: Vec<f32>,
    cursor: Option<Cursor>,
    done: bool,
}

impl EngineSession {
    /// Mint a session over shared weights, pre-sizing every buffer
    /// (the per-call hot path then never allocates).
    pub fn new(model: Arc<Model>) -> EngineSession {
        let spec = engine_spec(&model);
        EngineSession {
            acts: Activations::for_model(&model),
            input: vec![0.0; spec.in_dim],
            out_buf: vec![0.0; spec.out_dim],
            model,
            spec,
            cursor: None,
            done: false,
        }
    }
}

impl Session for EngineSession {
    fn name(&self) -> &'static str {
        "engine"
    }

    fn spec(&self) -> ModelSpec {
        self.spec.clone()
    }

    fn infer_into(&mut self, x: &[f32], out: &mut [f32]) -> Result<(), InferenceError> {
        // Single-shot and partial evaluation share this session's
        // activation buffers: running one while a partial inference is
        // suspended would silently corrupt its state. (Other sessions
        // are unaffected — the restriction is per-session now.)
        if self.cursor.is_some() {
            return Err(InferenceError::SessionState {
                backend: "engine".into(),
                expected: "idle (a partial inference is in flight)",
            });
        }
        // Validate against the cached buffer lengths: `spec()` walks
        // every layer and this is the zero-allocation hot path.
        if x.len() != self.input.len() {
            return Err(InferenceError::ShapeMismatch {
                what: "input",
                expected: self.input.len(),
                got: x.len(),
            });
        }
        if out.len() != self.out_buf.len() {
            return Err(InferenceError::ShapeMismatch {
                what: "output",
                expected: self.out_buf.len(),
                got: out.len(),
            });
        }
        self.model.infer_with(&mut self.acts, x, out);
        Ok(())
    }

    fn partial(&mut self) -> Option<&mut dyn PartialSession> {
        Some(self)
    }
}

impl PartialSession for EngineSession {
    fn begin(&mut self, x: &[f32]) -> Result<(), InferenceError> {
        if x.len() != self.input.len() {
            return Err(InferenceError::ShapeMismatch {
                what: "input",
                expected: self.input.len(),
                got: x.len(),
            });
        }
        self.input.copy_from_slice(x);
        self.cursor = Some(Cursor::default());
        self.done = false;
        Ok(())
    }

    fn in_flight(&self) -> bool {
        self.cursor.is_some()
    }

    fn remaining_rows(&self) -> usize {
        match self.cursor {
            Some(c) => self.model.remaining_rows(c),
            None => 0,
        }
    }

    fn next_row_macs(&self) -> f64 {
        let Some(c) = self.cursor else { return 0.0 };
        let layers = self.model.layers();
        if c.layer >= layers.len() {
            return 0.0;
        }
        let l = &layers[c.layer];
        l.macs() as f64 / l.chunk_rows().max(1) as f64
    }

    fn step(&mut self, row_budget: usize) -> Result<usize, InferenceError> {
        let Some(c) = self.cursor else {
            return Err(InferenceError::SessionState {
                backend: "engine".into(),
                expected: "begun",
            });
        };
        if self.done || row_budget == 0 {
            return Ok(0);
        }
        let before = self.model.remaining_rows(c);
        let (c, done) = self.model.infer_partial_with(
            &mut self.acts,
            &self.input,
            c,
            row_budget,
            &mut self.out_buf,
        );
        self.cursor = Some(c);
        self.done = done;
        Ok(before - self.model.remaining_rows(c))
    }

    fn finished(&self) -> bool {
        self.done
    }

    fn finish(&mut self, out: &mut [f32]) -> Result<(), InferenceError> {
        if !self.done {
            return Err(InferenceError::SessionState {
                backend: "engine".into(),
                expected: "finished",
            });
        }
        if out.len() != self.out_buf.len() {
            return Err(InferenceError::ShapeMismatch {
                what: "output",
                expected: self.out_buf.len(),
                got: out.len(),
            });
        }
        out.copy_from_slice(&self.out_buf);
        self.cursor = None;
        self.done = false;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// ST PLC (bytecode VM)
// ---------------------------------------------------------------------

/// ST backend: the ported ICSML program running on the simulated PLC.
///
/// The handle is `Send + Sync`: it holds the once-compiled bytecode
/// ([`CodeUnit`] behind `Arc`) and a [`HostImage`] snapshot of the
/// adopted interpreter state (globals, instances, `io_dir`, meter —
/// any host-side mutation applied before construction is captured).
/// Every session restores the image into a private [`Vm`] — sessions
/// share code and the image, never runtime state.
///
/// Scans execute on the bytecode [`Vm`] — the ST runtime's fast tier.
/// The tree-walking [`Interp`] remains the reference oracle (the
/// constructor consumes one and adopts its state), and the two tiers
/// are bit-equivalent in outputs *and* meters, so the §6.3 cost
/// accounting below is unchanged (`tests/st_differential.rs`).
pub struct StBackend {
    code: Arc<CodeUnit>,
    image: Arc<HostImage>,
    program: String,
    dims: (usize, usize),
    plan: RowPlan,
}

impl StBackend {
    /// Compile the interpreter's unit to bytecode, snapshot its state,
    /// and probe the program's I/O dims. Errors with a typed
    /// [`InferenceError::BackendUnavailable`] when the program is
    /// missing or its `inputs`/`outputs` are not `ARRAY OF REAL`.
    pub fn new(
        interp: Interp,
        program: impl Into<String>,
    ) -> Result<StBackend, InferenceError> {
        let program = program.into();
        let vm = Vm::from_interp(interp);
        let dims = probe_dims(&vm, &program).ok_or_else(|| {
            InferenceError::BackendUnavailable {
                backend: "st".into(),
                reason: format!(
                    "program {program} not found or missing inputs/outputs \
                     ARRAY OF REAL fields"
                ),
            }
        })?;
        let code = Arc::clone(vm.code());
        let image = Arc::new(vm.host.image());
        Ok(StBackend {
            code,
            image,
            program,
            dims,
            plan: RowPlan::single(dims.0, dims.1),
        })
    }

    /// Attach the model's real layer structure so multipart scheduling
    /// budgets rows at engine fidelity (e.g.
    /// `RowPlan::from_layer_sizes(&spec.sizes)`).
    pub fn with_plan(mut self, plan: RowPlan) -> StBackend {
        self.plan = plan;
        self
    }

    /// Build a backend over the program a §2.7 TASK runs: the unit
    /// must carry a CONFIGURATION block whose named task binds exactly
    /// one program instance (the ML task of a multi-task controller).
    /// The resulting sessions serve that program — partial (§6.3)
    /// stepping included — while the rest of the configuration keeps
    /// running under its own `TaskScheduler`.
    pub fn for_task(
        interp: Interp,
        task: &str,
    ) -> Result<StBackend, InferenceError> {
        let unavailable = |reason: String| InferenceError::BackendUnavailable {
            backend: "st".into(),
            reason,
        };
        let model = interp
            .task_model()
            .cloned()
            .ok_or_else(|| {
                unavailable("unit has no CONFIGURATION block".into())
            })?;
        let ti = model.find_task(task).ok_or_else(|| {
            unavailable(format!("no TASK {task} in the configuration"))
        })?;
        let program = match model.tasks[ti].programs.as_slice() {
            [one] => interp.unit.programs[one.program].name.clone(),
            other => {
                return Err(unavailable(format!(
                    "TASK {task} binds {} program instances (need \
                     exactly one)",
                    other.len()
                )))
            }
        };
        StBackend::new(interp, program)
    }
}

fn probe_dims(vm: &Vm, program: &str) -> Option<(usize, usize)> {
    let inst = vm.program_instance(program)?;
    let i = match vm.instance_field(inst, "inputs") {
        Some(Value::ArrF32(a)) => a.borrow().len(),
        _ => return None,
    };
    let o = match vm.instance_field(inst, "outputs") {
        Some(Value::ArrF32(a)) => a.borrow().len(),
        _ => return None,
    };
    Some((i, o))
}

fn st_spec(dims: (usize, usize)) -> ModelSpec {
    ModelSpec {
        in_dim: dims.0,
        out_dim: dims.1,
        supports_partial: true,
        supports_meter: true,
        quantization: None,
        batch_granularity: 1,
    }
}

impl Backend for StBackend {
    fn name(&self) -> &'static str {
        "st"
    }

    fn spec(&self) -> ModelSpec {
        st_spec(self.dims)
    }

    fn session(&self) -> Result<Box<dyn Session>, InferenceError> {
        let host = Host::from_image(&self.image);
        let vm = Vm::with_host(host, Arc::clone(&self.code));
        Ok(Box::new(StSession {
            vm,
            program: self.program.clone(),
            last: Meter::new(),
            dims: self.dims,
            plan: self.plan.clone(),
            input: vec![0.0; self.dims.0],
            out_buf: vec![0.0; self.dims.1],
            rows_done: 0,
            active: false,
            done: false,
        }))
    }
}

/// One caller's ST session: a private [`Vm`] (restored from the
/// backend's state image) plus request buffers. The generated
/// programs' lazy first-scan initialization (BINARR weight loading)
/// runs once per session, against the backend's captured `io_dir`.
///
/// The ST substrate cannot pause mid-POU, so the partial sub-API
/// emulates §6.3 scheduling: `step` advances a row cursor through the
/// model's [`RowPlan`] (cost accounting, cycle counts and latency are
/// therefore faithful to the schedule) and the POU executes once on
/// the completing step. The output is schedule-invariant by
/// construction and cross-checked against the engine in the
/// coordinator tests.
pub struct StSession {
    /// The session's private VM (public so hosts can poke PLC state —
    /// globals, instance fields — between scans, as the examples do).
    pub vm: Vm,
    program: String,
    last: Meter,
    dims: (usize, usize),
    plan: RowPlan,
    input: Vec<f32>,
    out_buf: Vec<f32>,
    rows_done: usize,
    active: bool,
    done: bool,
}

impl StSession {
    /// Run one scan of the POU: `self.input` → program → `self.out_buf`.
    fn run_program_io(&mut self) -> Result<(), InferenceError> {
        let inst = self
            .vm
            .program_instance(&self.program)
            .ok_or_else(|| InferenceError::BackendUnavailable {
                backend: "st".into(),
                reason: format!("no program {}", self.program),
            })?;
        match self.vm.instance_field(inst, "inputs") {
            Some(Value::ArrF32(a)) => {
                let mut b = a.borrow_mut();
                // Program arrays disagreeing with the probed dims is
                // backend-side drift, not a caller shape bug.
                if b.len() != self.input.len() {
                    return Err(InferenceError::BackendUnavailable {
                        backend: "st".into(),
                        reason: format!(
                            "program inputs length {} != probed {}",
                            b.len(),
                            self.input.len()
                        ),
                    });
                }
                b.copy_from_slice(&self.input);
            }
            other => {
                return Err(InferenceError::BackendUnavailable {
                    backend: "st".into(),
                    reason: format!("bad inputs field: {other:?}"),
                })
            }
        }
        let before = self.vm.meter.clone();
        self.vm.run_program(&self.program).map_err(|e| {
            InferenceError::ExecutionFailed {
                backend: "st".into(),
                source: anyhow::anyhow!("{e}"),
            }
        })?;
        self.last = self.vm.meter.since(&before);
        match self.vm.instance_field(inst, "outputs") {
            Some(Value::ArrF32(a)) => {
                let b = a.borrow();
                if b.len() != self.out_buf.len() {
                    return Err(InferenceError::BackendUnavailable {
                        backend: "st".into(),
                        reason: format!(
                            "program outputs length {} != probed {}",
                            b.len(),
                            self.out_buf.len()
                        ),
                    });
                }
                self.out_buf.copy_from_slice(&b);
                Ok(())
            }
            other => Err(InferenceError::BackendUnavailable {
                backend: "st".into(),
                reason: format!("bad outputs field: {other:?}"),
            }),
        }
    }
}

impl Session for StSession {
    fn name(&self) -> &'static str {
        "st"
    }

    fn spec(&self) -> ModelSpec {
        st_spec(self.dims)
    }

    fn infer_into(&mut self, x: &[f32], out: &mut [f32]) -> Result<(), InferenceError> {
        // `input` doubles as the latched input of a suspended partial
        // inference — refuse to clobber it mid-flight.
        if self.active {
            return Err(InferenceError::SessionState {
                backend: "st".into(),
                expected: "idle (a partial inference is in flight)",
            });
        }
        check_shapes(&self.spec(), x, out)?;
        self.input.copy_from_slice(x);
        self.run_program_io()?;
        out.copy_from_slice(&self.out_buf);
        Ok(())
    }

    fn last_meter(&self) -> Option<Meter> {
        Some(self.last.clone())
    }

    fn partial(&mut self) -> Option<&mut dyn PartialSession> {
        Some(self)
    }
}

impl PartialSession for StSession {
    fn begin(&mut self, x: &[f32]) -> Result<(), InferenceError> {
        if x.len() != self.input.len() {
            return Err(InferenceError::ShapeMismatch {
                what: "input",
                expected: self.input.len(),
                got: x.len(),
            });
        }
        self.input.copy_from_slice(x);
        self.rows_done = 0;
        self.active = true;
        self.done = false;
        Ok(())
    }

    fn in_flight(&self) -> bool {
        self.active
    }

    fn remaining_rows(&self) -> usize {
        if !self.active || self.done {
            return 0;
        }
        self.plan.total_rows() - self.rows_done
    }

    fn next_row_macs(&self) -> f64 {
        if !self.active || self.done {
            return 0.0;
        }
        self.plan.row_macs(self.rows_done)
    }

    fn step(&mut self, row_budget: usize) -> Result<usize, InferenceError> {
        if !self.active {
            return Err(InferenceError::SessionState {
                backend: "st".into(),
                expected: "begun",
            });
        }
        if self.done || row_budget == 0 {
            return Ok(0);
        }
        let total = self.plan.total_rows();
        let consumed = row_budget.min(total - self.rows_done);
        // Run the POU before committing the completing rows: a
        // transient interpreter error leaves the inference one step
        // short, so the next `step` retries instead of wedging at
        // rows_done == total with done == false.
        if self.rows_done + consumed >= total {
            self.run_program_io()?;
            self.done = true;
        }
        self.rows_done += consumed;
        Ok(consumed)
    }

    fn finished(&self) -> bool {
        self.done
    }

    fn finish(&mut self, out: &mut [f32]) -> Result<(), InferenceError> {
        if !self.done {
            return Err(InferenceError::SessionState {
                backend: "st".into(),
                expected: "finished",
            });
        }
        if out.len() != self.out_buf.len() {
            return Err(InferenceError::ShapeMismatch {
                what: "output",
                expected: self.out_buf.len(),
                got: out.len(),
            });
        }
        out.copy_from_slice(&self.out_buf);
        self.active = false;
        self.done = false;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Act;

    fn toy() -> Model {
        Model::new(vec![
            Layer::Input { dim: 4 },
            Layer::dense(
                (0..12).map(|i| (i as f32) * 0.1 - 0.6).collect(),
                vec![0.1, -0.1, 0.2],
                4,
                Act::Relu,
            ),
            Layer::dense(
                (0..6).map(|i| 0.3 - (i as f32) * 0.07).collect(),
                vec![0.05, -0.3],
                3,
                Act::None,
            ),
        ])
    }

    #[test]
    fn engine_spec_reports_capabilities() {
        let b = EngineBackend::new(toy());
        let s = b.spec();
        assert_eq!((s.in_dim, s.out_dim), (4, 2));
        assert!(s.supports_partial);
        assert!(!s.supports_meter);
        assert_eq!(s.quantization, None);
    }

    #[test]
    fn engine_infer_into_matches_infer() {
        let b = EngineBackend::new(toy());
        let mut s = b.session().unwrap();
        let x = [0.4, -0.2, 0.9, 1.4];
        let via_vec = s.infer(&x).unwrap();
        let mut out = [0.0f32; 2];
        s.infer_into(&x, &mut out).unwrap();
        assert_eq!(out.to_vec(), via_vec);
    }

    #[test]
    fn engine_shape_mismatch_is_typed() {
        let b = EngineBackend::new(toy());
        let mut s = b.session().unwrap();
        let mut out = [0.0f32; 2];
        match s.infer_into(&[1.0; 3], &mut out) {
            Err(InferenceError::ShapeMismatch { expected: 4, got: 3, .. }) => {}
            other => panic!("want ShapeMismatch, got {other:?}"),
        }
        match s.infer_into(&[1.0; 4], &mut out[..1]) {
            Err(InferenceError::ShapeMismatch { expected: 2, got: 1, .. }) => {}
            other => panic!("want ShapeMismatch, got {other:?}"),
        }
    }

    #[test]
    fn engine_partial_session_matches_single_shot() {
        let x = [0.7, -0.4, 1.1, 0.2];
        let b = EngineBackend::new(toy());
        let want = b.session().unwrap().infer(&x).unwrap();
        let mut s = b.session().unwrap();
        let p = s.partial().expect("engine supports partial");
        p.begin(&x).unwrap();
        assert!(p.in_flight());
        let mut steps = 0;
        while !p.finished() {
            assert!(p.next_row_macs() >= 0.0);
            assert!(p.step(2).unwrap() >= 1);
            steps += 1;
            assert!(steps < 100, "did not converge");
        }
        assert_eq!(p.remaining_rows(), 0);
        let mut out = [0.0f32; 2];
        p.finish(&mut out).unwrap();
        assert_eq!(out.to_vec(), want);
        assert!(!p.in_flight());
    }

    #[test]
    fn engine_step_before_begin_is_session_error() {
        let b = EngineBackend::new(toy());
        let mut s = EngineSession::new(Arc::clone(b.model()));
        match PartialSession::step(&mut s, 1) {
            Err(InferenceError::SessionState { .. }) => {}
            other => panic!("want SessionState, got {other:?}"),
        }
        let mut out = [0.0f32; 2];
        match PartialSession::finish(&mut s, &mut out) {
            Err(InferenceError::SessionState { .. }) => {}
            other => panic!("want SessionState, got {other:?}"),
        }
    }

    #[test]
    fn infer_into_rejected_while_partial_in_flight() {
        let b = EngineBackend::new(toy());
        let x = [0.1f32, 0.2, 0.3, 0.4];
        let want = b.session().unwrap().infer(&x).unwrap();
        let mut s = EngineSession::new(Arc::clone(b.model()));
        PartialSession::begin(&mut s, &x).unwrap();
        s.step(2).unwrap();
        // A single-shot call mid-flight would corrupt the suspended
        // activations — it must be refused, not silently served.
        let mut out = [0.0f32; 2];
        match Session::infer_into(&mut s, &x, &mut out) {
            Err(InferenceError::SessionState { .. }) => {}
            other => panic!("want SessionState, got {other:?}"),
        }
        // The partial inference itself is unharmed and completes
        // correctly.
        while !s.finished() {
            s.step(2).unwrap();
        }
        PartialSession::finish(&mut s, &mut out).unwrap();
        assert_eq!(out.to_vec(), want);
        // Idle again: single-shot works.
        Session::infer_into(&mut s, &x, &mut out).unwrap();
    }

    #[test]
    fn default_batch_equals_sequential() {
        let b = EngineBackend::new(toy());
        let mut s = b.session().unwrap();
        let xs: Vec<f32> = (0..12).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut batched = vec![0.0f32; 6];
        assert_eq!(s.infer_batch(&xs, &mut batched).unwrap(), 3);
        for i in 0..3 {
            let one = s.infer(&xs[i * 4..(i + 1) * 4]).unwrap();
            assert_eq!(&batched[i * 2..(i + 1) * 2], &one[..]);
        }
    }

    #[test]
    fn batch_shape_errors_are_typed() {
        let b = EngineBackend::new(toy());
        let mut s = b.session().unwrap();
        let mut out = vec![0.0f32; 2];
        match s.infer_batch(&[0.0; 5], &mut out) {
            Err(InferenceError::ShapeMismatch { what: "batch input", .. }) => {}
            other => panic!("want batch input mismatch, got {other:?}"),
        }
        match s.infer_batch(&[0.0; 8], &mut out[..1]) {
            Err(InferenceError::ShapeMismatch { what: "batch output", .. }) => {}
            other => panic!("want batch output mismatch, got {other:?}"),
        }
    }

    #[test]
    fn sessions_over_one_backend_are_independent() {
        let b = EngineBackend::new(toy());
        let xa = [0.4, -0.2, 0.9, 1.4];
        let xb = [-0.3, 0.8, -1.2, 0.5];
        let want_a = b.session().unwrap().infer(&xa).unwrap();
        let want_b = b.session().unwrap().infer(&xb).unwrap();
        // Suspend a partial inference in session 1, serve single-shot
        // traffic from session 2, then resume 1 — the old design
        // refused this with a `SessionState` error at backend scope.
        let mut s1 = b.session().unwrap();
        let mut s2 = b.session().unwrap();
        let p1 = s1.partial().unwrap();
        p1.begin(&xa).unwrap();
        p1.step(2).unwrap();
        assert_eq!(s2.infer(&xb).unwrap(), want_b);
        let p1 = s1.partial().unwrap();
        while !p1.finished() {
            p1.step(3).unwrap();
        }
        let mut out = [0.0f32; 2];
        p1.finish(&mut out).unwrap();
        assert_eq!(out.to_vec(), want_a);
    }
}
