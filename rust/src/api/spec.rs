//! Capability descriptors: what a backend's model looks like and what
//! the backend can do with it.

use crate::quant::Scheme;

/// Shape + capability descriptor returned by [`crate::api::Backend::spec`].
///
/// Consumers negotiate against this instead of downcasting to concrete
/// backend types: the multipart coordinator checks `supports_partial`,
/// the PLC cost reports check `supports_meter`, quantized serving
/// checks `quantization`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Flattened input feature count.
    pub in_dim: usize,
    /// Flattened output (logit) count.
    pub out_dim: usize,
    /// The backend can run resumable `begin`/`step`/`finish` sessions
    /// (paper §6.3 multipart inference).
    pub supports_partial: bool,
    /// The backend meters ST instruction costs per inference
    /// ([`crate::api::Session::last_meter`] returns `Some`).
    pub supports_meter: bool,
    /// Integer quantization scheme the weights are stored in, if any
    /// (paper §6.1); `None` means f32 (`REAL`).
    pub quantization: Option<Scheme>,
    /// Batch sizes the substrate can execute must be multiples of
    /// this (1 everywhere except fixed-batch AOT executables, where it
    /// is the compiled batch dimension). Schedulers — notably
    /// `serve::Pool`'s micro-batcher — use it to cut servable chunks
    /// instead of submitting doomed ragged batches.
    pub batch_granularity: usize,
}

impl ModelSpec {
    /// A plain f32 single-shot model — the common case; flip the
    /// capability flags on the result as needed.
    pub fn dense_f32(in_dim: usize, out_dim: usize) -> ModelSpec {
        ModelSpec {
            in_dim,
            out_dim,
            supports_partial: false,
            supports_meter: false,
            quantization: None,
            batch_granularity: 1,
        }
    }
}

/// One schedulable chunk of a resumable inference: `rows` rows, each
/// costing `macs_per_row` multiply-accumulates in the PLC timing model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RowChunk {
    /// Number of schedulable rows in this chunk.
    pub rows: usize,
    /// Modeled multiply-accumulates each row costs.
    pub macs_per_row: f64,
}

/// The row-level execution plan of a model, used by backends whose
/// substrate cannot pause mid-layer (the ST interpreter) to expose a
/// §6.3-schedulable cost structure, and by the coordinator to budget
/// cycles.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RowPlan {
    /// The plan's chunks, in execution order.
    pub chunks: Vec<RowChunk>,
}

impl RowPlan {
    /// Plan for a dense MLP given its layer sizes
    /// (`[in, hidden.., out]`): layer *i* contributes `sizes[i+1]` rows
    /// of `sizes[i]` MACs each — exactly the engine's chunking.
    pub fn from_layer_sizes(sizes: &[usize]) -> RowPlan {
        let chunks = sizes
            .windows(2)
            .map(|w| RowChunk { rows: w[1], macs_per_row: w[0] as f64 })
            .collect();
        RowPlan { chunks }
    }

    /// Degenerate single-chunk plan (used when only total dims are
    /// known: `out_dim` rows of `in_dim` MACs).
    pub fn single(in_dim: usize, out_dim: usize) -> RowPlan {
        RowPlan {
            chunks: vec![RowChunk {
                rows: out_dim.max(1),
                macs_per_row: in_dim as f64,
            }],
        }
    }

    /// Total schedulable rows across every chunk.
    pub fn total_rows(&self) -> usize {
        self.chunks.iter().map(|c| c.rows).sum()
    }

    /// MACs of the row at global row index `pos` (row indices run
    /// through the chunks in order). Returns 0.0 past the end.
    pub fn row_macs(&self, pos: usize) -> f64 {
        let mut seen = 0usize;
        for c in &self.chunks {
            if pos < seen + c.rows {
                return c.macs_per_row;
            }
            seen += c.rows;
        }
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_from_sizes_matches_engine_chunking() {
        let p = RowPlan::from_layer_sizes(&[8, 16, 4]);
        assert_eq!(p.total_rows(), 20);
        assert_eq!(p.row_macs(0), 8.0);
        assert_eq!(p.row_macs(15), 8.0);
        assert_eq!(p.row_macs(16), 16.0);
        assert_eq!(p.row_macs(19), 16.0);
        assert_eq!(p.row_macs(20), 0.0);
    }

    #[test]
    fn single_plan_never_empty() {
        let p = RowPlan::single(400, 0);
        assert_eq!(p.total_rows(), 1);
    }
}
