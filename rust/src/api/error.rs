//! Typed inference errors.
//!
//! The old `defense::Backend` reported every failure as an untyped
//! `anyhow!` string, which made router policy impossible: a shape bug
//! (caller error, never retry) was indistinguishable from a flaky
//! backend (retry elsewhere). Consumers that don't care still get free
//! conversion into `anyhow::Error` via `?`.

use std::fmt;

/// Why an inference request failed.
#[derive(Debug)]
pub enum InferenceError {
    /// An input/output buffer had the wrong length for the model.
    ShapeMismatch {
        /// Which buffer ("input", "output", "batch input", ...).
        what: &'static str,
        /// Length the model requires.
        expected: usize,
        /// Length the caller supplied.
        got: usize,
    },
    /// The backend exists but cannot serve right now (missing
    /// artifacts, uninitialized program instance, ...).
    BackendUnavailable {
        /// Which backend refused ("engine", "st", "xla", "pool", ...).
        backend: String,
        /// Human-readable refusal reason.
        reason: String,
    },
    /// The backend does not implement the requested operation
    /// (e.g. partial inference on a single-shot substrate).
    Unsupported {
        /// Which backend refused.
        backend: String,
        /// The unimplemented operation.
        op: &'static str,
    },
    /// The backend tried and failed mid-execution.
    ExecutionFailed {
        /// Which backend failed.
        backend: String,
        /// The underlying execution error.
        source: anyhow::Error,
    },
    /// A partial-session call arrived in the wrong state
    /// (`step` before `begin`, `finish` before completion, ...).
    SessionState {
        /// Which backend's session refused.
        backend: String,
        /// The state the call required.
        expected: &'static str,
    },
    /// The request's deadline passed (or provably cannot be met)
    /// before it was served — the request is *shed*, never answered
    /// late (`serve::Pool` scheduling, PR 4). Not a backend fault: it
    /// signals load or an infeasible budget, not broken hardware.
    DeadlineExceeded {
        /// Where the miss was detected: `"admission"` (rejected at
        /// ingress by the cost-model gate), `"queue"` (expired while
        /// waiting for a worker), or `"router"` (expired between
        /// fallback attempts).
        stage: &'static str,
        /// Microseconds by which the deadline was — or, for admission
        /// rejections, would have been — missed.
        late_us: f64,
    },
    /// No model by the requested name exists in the serving registry
    /// (`netserve::ModelRegistry`): none of the configured manifest
    /// roots export it. A caller-side error — retrying elsewhere or
    /// later cannot help.
    ModelNotFound {
        /// The model name the request asked for.
        model: String,
    },
    /// The model is known to the registry but cannot be made (or
    /// kept) resident under the registry's configured engine/byte
    /// budget — e.g. its weights alone exceed the whole budget. A
    /// capacity condition, not a broken backend.
    Evicted {
        /// The model that lost (or could not gain) residency.
        model: String,
    },
    /// The backend panicked mid-execution. The panic was contained by
    /// the pool's per-job `catch_unwind` (`serve::Pool` supervision):
    /// only this request failed, the worker is respawned, and the
    /// backend is quarantined after K consecutive faults. A backend
    /// fault — a router should penalize and retry elsewhere.
    BackendPanicked {
        /// Which backend panicked.
        backend: String,
        /// The panic payload, rendered to a string when possible.
        message: String,
    },
    /// The serving tier is at its in-flight capacity and refused the
    /// request outright instead of queueing it unboundedly
    /// (`netserve` connection / server caps). Not a backend fault: it
    /// signals load, and the caller should back off and retry.
    Overloaded {
        /// Which limit was hit: `"connection"` (per-connection
        /// in-flight cap) or `"server"` (global in-flight cap).
        scope: &'static str,
        /// Suggested client backoff before retrying, in microseconds.
        retry_after_us: f64,
    },
    /// The transport connection died with requests still in flight;
    /// their replies are unrecoverable (the server answers over the
    /// connection they arrived on). The client's reconnect path
    /// surfaces this after re-establishing the connection, so
    /// *subsequent* requests succeed. Treated as a backend fault so
    /// routers penalize the flaky route.
    ConnectionLost {
        /// Wire ids of the in-flight requests whose replies were lost.
        lost_ids: Vec<u64>,
        /// Why the connection died.
        reason: String,
    },
    /// A router had no backends registered.
    NoBackends,
    /// A router exhausted every candidate backend.
    AllBackendsFailed {
        /// (backend name, error description) per attempt, in try order.
        failures: Vec<(String, String)>,
    },
}

impl InferenceError {
    /// True when the fault lies with the backend (flaky execution,
    /// missing artifacts, bad session state, contained panics, dead
    /// transport) — the class a router should penalize and retry
    /// elsewhere. False for caller-side errors
    /// ([`InferenceError::ShapeMismatch`],
    /// [`InferenceError::ModelNotFound`]), load/deadline/capacity
    /// sheds ([`InferenceError::DeadlineExceeded`],
    /// [`InferenceError::Evicted`], [`InferenceError::Overloaded`])
    /// and router aggregates, which say nothing about the backend's
    /// health.
    pub fn is_backend_fault(&self) -> bool {
        matches!(
            self,
            InferenceError::BackendUnavailable { .. }
                | InferenceError::Unsupported { .. }
                | InferenceError::ExecutionFailed { .. }
                | InferenceError::SessionState { .. }
                | InferenceError::BackendPanicked { .. }
                | InferenceError::ConnectionLost { .. }
        )
    }
}

impl fmt::Display for InferenceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            InferenceError::ShapeMismatch { what, expected, got } => {
                write!(f, "shape mismatch: {what} has length {got}, model expects {expected}")
            }
            InferenceError::BackendUnavailable { backend, reason } => {
                write!(f, "backend {backend} unavailable: {reason}")
            }
            InferenceError::Unsupported { backend, op } => {
                write!(f, "backend {backend} does not support {op}")
            }
            InferenceError::ExecutionFailed { backend, source } => {
                write!(f, "backend {backend} execution failed: {source}")
            }
            InferenceError::SessionState { backend, expected } => {
                write!(
                    f,
                    "backend {backend}: invalid session state, expected {expected}"
                )
            }
            InferenceError::DeadlineExceeded { stage, late_us } => {
                write!(
                    f,
                    "deadline exceeded at {stage} by {late_us:.1} us \
                     (request shed, not served late)"
                )
            }
            InferenceError::ModelNotFound { model } => {
                write!(f, "model {model:?} is not in the registry")
            }
            InferenceError::Evicted { model } => {
                write!(
                    f,
                    "model {model:?} cannot be resident under the \
                     registry budget (evicted)"
                )
            }
            InferenceError::BackendPanicked { backend, message } => {
                write!(
                    f,
                    "backend {backend} panicked (contained): {message}"
                )
            }
            InferenceError::Overloaded { scope, retry_after_us } => {
                write!(
                    f,
                    "overloaded at the {scope} in-flight cap; retry \
                     after {retry_after_us:.0} us"
                )
            }
            InferenceError::ConnectionLost { lost_ids, reason } => {
                write!(
                    f,
                    "connection lost with {} request(s) in flight \
                     ({reason})",
                    lost_ids.len()
                )
            }
            InferenceError::NoBackends => write!(f, "no backends registered"),
            InferenceError::AllBackendsFailed { failures } => {
                write!(f, "all {} backend(s) failed:", failures.len())?;
                for (name, err) in failures {
                    write!(f, " [{name}: {err}]")?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for InferenceError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            InferenceError::ExecutionFailed { source, .. } => {
                let e: &(dyn std::error::Error + Send + Sync + 'static) =
                    source.as_ref();
                Some(e)
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = InferenceError::ShapeMismatch {
            what: "input",
            expected: 400,
            got: 3,
        };
        let s = e.to_string();
        assert!(s.contains("400") && s.contains("3") && s.contains("input"));
    }

    #[test]
    fn converts_into_anyhow() {
        fn fails() -> anyhow::Result<()> {
            Err(InferenceError::NoBackends)?
        }
        let err = fails().unwrap_err();
        assert!(err.downcast_ref::<InferenceError>().is_some());
    }

    #[test]
    fn deadline_exceeded_is_not_a_backend_fault() {
        let e = InferenceError::DeadlineExceeded {
            stage: "queue",
            late_us: 12.5,
        };
        assert!(!e.is_backend_fault(), "a shed says nothing about health");
        let s = e.to_string();
        assert!(s.contains("queue") && s.contains("12.5"));
    }

    #[test]
    fn registry_errors_are_not_backend_faults() {
        let missing = InferenceError::ModelNotFound { model: "nope".into() };
        assert!(!missing.is_backend_fault(), "a bad name is a caller error");
        assert!(missing.to_string().contains("nope"));
        let evicted = InferenceError::Evicted { model: "big".into() };
        assert!(!evicted.is_backend_fault(), "capacity says nothing of health");
        assert!(evicted.to_string().contains("big"));
    }

    #[test]
    fn panicked_and_lost_are_backend_faults_overload_is_not() {
        let p = InferenceError::BackendPanicked {
            backend: "engine".into(),
            message: "index out of bounds".into(),
        };
        assert!(p.is_backend_fault(), "a panic is the backend's fault");
        assert!(p.to_string().contains("engine"));
        assert!(p.to_string().contains("index out of bounds"));

        let lost = InferenceError::ConnectionLost {
            lost_ids: vec![3, 9],
            reason: "peer reset".into(),
        };
        assert!(lost.is_backend_fault(), "a dead route is penalized");
        assert!(lost.to_string().contains("2 request(s)"));

        let busy = InferenceError::Overloaded {
            scope: "server",
            retry_after_us: 1500.0,
        };
        assert!(!busy.is_backend_fault(), "load says nothing of health");
        let s = busy.to_string();
        assert!(s.contains("server") && s.contains("1500"));
    }

    #[test]
    fn execution_failed_preserves_source() {
        let e = InferenceError::ExecutionFailed {
            backend: "xla".into(),
            source: anyhow::anyhow!("pjrt: device lost"),
        };
        assert!(std::error::Error::source(&e)
            .unwrap()
            .to_string()
            .contains("device lost"));
    }
}
