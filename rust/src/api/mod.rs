//! The inference API: the vendor-neutral contract every execution
//! substrate implements (paper §4's framework API + §6.3 multipart
//! inference, generalized to a serving system).
//!
//! The paper's point is that ML inference should run natively on *any*
//! IEC 61131-3 runtime; this module is the Rust expression of that
//! portability claim — scaled past the paper's one-PLC framing. The
//! contract is two-level:
//!
//! * [`Backend`] — an **immutable model handle**: weights, compiled
//!   ST bytecode, XLA executables behind `Arc`; identity and
//!   capability queries over `&self`. The in-crate backends are
//!   `Send + Sync` ([`SharedBackend`]), so one handle serves any
//!   number of threads.
//! * [`Session`] — **per-request mutable state** minted by
//!   [`Backend::session`]: scratch buffers, the resumable §6.3
//!   `begin`/`step`/`finish` cursor ([`PartialSession`]), the last
//!   [`crate::st::Meter`]. One caller per session; concurrency is many
//!   sessions, not locks.
//!
//! Everything that executes a model — the native engine, the
//! ST-interpreter PLC, the XLA/PJRT runtime — implements [`Backend`];
//! everything that consumes inference — the §7 detector, the router,
//! the §6.3 multipart scheduler, `serve::Pool`, the serving CLI — is
//! written against the traits, never against a concrete substrate.
//!
//! Contract highlights (see `API.md` at the repo root):
//!
//! * **Allocation-free hot path** — [`Session::infer_into`] writes
//!   logits into a caller-provided buffer; the engine session performs
//!   no heap allocation per call (asserted by `tests/api_contract.rs`).
//! * **Batch-first** — [`Session::infer_batch`] serves N requests in
//!   one call. The default implementation loops `infer_into`; sessions
//!   with true batched execution (XLA) override it.
//! * **Concurrent by construction** — N threads × M sessions over one
//!   shared backend produce bit-identical results to sequential
//!   execution (asserted by `tests/concurrency.rs`).
//! * **Typed errors** — [`InferenceError`] replaces ad-hoc `anyhow!`
//!   strings so routers can distinguish a shape bug from a flaky
//!   backend.
//! * **Capability discovery** — [`ModelSpec`] reports dimensions and
//!   what the backend can do (`supports_partial`, `supports_meter`,
//!   `quantization`), so schedulers negotiate instead of downcasting.
//! * **Resumable inference** — [`PartialSession`] folds the §6.3
//!   `begin`/`step(row_budget)`/`finish` sub-API into the session;
//!   the multipart coordinator schedules over any capable session.
#![deny(missing_docs)]

pub mod backend;
pub mod backends;
pub mod error;
pub mod partial;
pub mod session;
pub mod spec;

pub use backend::{Backend, SharedBackend};
pub use backends::{EngineBackend, EngineSession, StBackend, StSession};
pub use error::InferenceError;
pub use partial::PartialSession;
pub use session::Session;
pub use spec::{ModelSpec, RowPlan};
