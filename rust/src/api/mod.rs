//! The inference API: the vendor-neutral contract every execution
//! substrate implements (paper §4's framework API + §6.3 multipart
//! inference, generalized).
//!
//! The paper's point is that ML inference should run natively on *any*
//! IEC 61131-3 runtime; this module is the Rust expression of that
//! portability claim. Everything that executes a model — the native
//! engine, the ST-interpreter PLC, the XLA/PJRT runtime — implements
//! [`Backend`], and everything that consumes inference — the §7
//! detector, the router, the §6.3 multipart scheduler, the serving
//! CLI — is written against the trait, never against a concrete
//! substrate.
//!
//! Contract highlights (see `API.md` at the repo root):
//!
//! * **Allocation-free hot path** — [`Backend::infer_into`] writes
//!   logits into a caller-provided buffer; the engine path performs no
//!   heap allocation per call (asserted by `tests/api_contract.rs`).
//! * **Batch-first** — [`Backend::infer_batch`] serves N requests in
//!   one call. The default implementation loops `infer_into`; backends
//!   with true batched execution (XLA) override it.
//! * **Typed errors** — [`InferenceError`] replaces ad-hoc `anyhow!`
//!   strings so routers can distinguish a shape bug from a flaky
//!   backend.
//! * **Capability discovery** — [`ModelSpec`] reports dimensions and
//!   what the backend can do (`supports_partial`, `supports_meter`,
//!   `quantization`), so schedulers negotiate instead of downcasting.
//! * **Resumable inference** — [`PartialBackend`] folds the §6.3
//!   `begin`/`step(row_budget)`/`finish` session into the contract;
//!   the multipart coordinator schedules over any capable backend.

pub mod backend;
pub mod backends;
pub mod error;
pub mod partial;
pub mod spec;

pub use backend::Backend;
pub use backends::{EngineBackend, StBackend};
pub use error::InferenceError;
pub use partial::PartialBackend;
pub use spec::{ModelSpec, RowPlan};
